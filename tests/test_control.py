"""Online adaptive control loop (DESIGN.md §13).

Contracts pinned here:

* ``WindowedLatency`` fed W ``RoundState``s is BIT-EXACT with a
  ``TraceLatency`` built over a trace of exactly those rounds (scalar and
  batch protocols), and the ring buffer keeps exactly the last W rounds;
* ``observe_round`` → ``reconstruct_state`` round-trips the fleet state:
  the window priced from reconstructed telemetry matches the window
  priced from the ground-truth states to float round-off, and absent
  clients report NaN durations;
* ``HsflProblem.evaluator`` rebuilds its memoized ``BatchedEvaluator``
  when the windowed model's ``version`` moves (the stale-table bugfix)
  and ``invalidate_caches`` drops it explicitly;
* ``piecewise_bound`` with one segment is bit-identical to
  ``theorem1_bound``; a constant-schedule split matches the static bound;
  mixed segments interpolate the per-schedule penalties, and the
  ε-progress ledger reproduces Corollary 1 for static schedules;
* warm-started BCD on the windowed problem finds the identical optimum a
  cold trace re-price + from-scratch solve finds;
* state migration (Engines A and B) preserves the global client-mean
  iterate and carries momentum/Adam moments through the same re-grouping;
  ``resume_with_migration`` applies it on checkpoint cut mismatch;
* ``Controller`` gating: min-window, cooldown, max-switches, and the
  no-drift fast path never fire the solver; real drift does;
* ``ControlCfg`` validation + spec JSON roundtrip, and the ``control``
  run mode end-to-end (slow).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.control import (
    BoundSegment,
    Controller,
    WindowedLatency,
    migrate_params_a,
    migrate_state,
    migrate_state_a,
    migrate_state_b,
    observe_round,
    piecewise_bound,
    progress_per_round,
    progress_target,
    reconstruct_state,
    resume_with_migration,
)
from repro.core import (
    HsflProblem,
    SystemSpec,
    build_profile,
    solve_bcd,
    synthetic_hyperspec,
    theorem1_bound,
)
from repro.core.convergence import corollary1_rounds
from repro.core.tiers import default_plan
from repro.sim import TraceLatency, make_trace
from repro.sim.scenarios import SystemTrace

CUTS = (3, 8)


def small_problem(seed=0, num_clients=8, num_edges=2):
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(
        num_clients=num_clients, num_edges=num_edges, seed=seed
    )
    hp = synthetic_hyperspec(VGG.n_units, num_clients, seed=seed)
    eps = theorem1_bound(hp, 500, (2, 2, 1), CUTS)
    return HsflProblem(prof, system, hp, eps)


def windowed(problem, trace, rounds, window=None, quantile=0.5):
    win = WindowedLatency(
        problem.profile, problem.system, problem.cut_lattice(),
        window=window or rounds, quantile=quantile,
    )
    for r in range(rounds):
        win.push(trace.round_state(r))
    return win


# --------------------------------------------------------------------------- #
# windowed system estimate
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("scenario", ["flaky-wan", "diurnal-churn"])
def test_windowed_bit_exact_vs_trace_latency(scenario):
    """The online window fed the same RoundStates as an offline trace
    prices the whole lattice bit-identically (batch and scalar paths)."""
    p = small_problem()
    trace = make_trace(scenario, p.profile, p.system, rounds=6, seed=1)
    win = windowed(p, trace, 6)
    tl = TraceLatency(trace, quantile=0.5, backend="numpy")
    lat = p.cut_lattice()
    np.testing.assert_array_equal(win.split_T_batch(lat), tl.split_T_batch(lat))
    np.testing.assert_array_equal(win.agg_T_batch(lat), tl.agg_T_batch(lat))
    for k in (0, len(lat) // 2, len(lat) - 1):
        cuts = tuple(int(c) for c in lat[k])
        assert win.split_T(cuts) == tl.split_T(cuts)
        for m in range(p.M - 1):
            assert win.agg_T(cuts, m) == tl.agg_T(cuts, m)


def test_window_keeps_exactly_last_w_rounds():
    """Pushing T > W rounds leaves the tables of the last W alone — the
    ring buffer ages old rounds out bit-exactly."""
    p = small_problem()
    trace = make_trace("flaky-wan", p.profile, p.system, rounds=7, seed=2)
    win = windowed(p, trace, 7, window=4)
    fresh = WindowedLatency(
        p.profile, p.system, p.cut_lattice(), window=4, quantile=0.5
    )
    for r in range(3, 7):
        fresh.push(trace.round_state(r))
    lat = p.cut_lattice()
    assert win.n_obs == fresh.n_obs == 4
    np.testing.assert_array_equal(
        win.split_T_batch(lat), fresh.split_T_batch(lat)
    )
    np.testing.assert_array_equal(win.agg_T_batch(lat), fresh.agg_T_batch(lat))
    assert len(win.states()) == 4
    assert all(
        np.array_equal(a.available, b.available)
        for a, b in zip(win.states(), [trace.round_state(r) for r in range(3, 7)])
    )


def test_table_cache_invalidates_across_eviction_wrap():
    """The memoized scalar tables must not survive the window wrap.

    The W+1-th push is the first one that *evicts* (n_obs stops moving at
    W, so a cache keyed on buffer length — instead of the version token —
    would serve the pre-wrap tables forever).  After exactly W+1 pushes
    the scalar ``split_T``/``agg_T`` must price the last W rounds, bit
    identical to a fresh window fed only those rounds, and to a
    ``TraceLatency`` over a trace of exactly those rounds."""
    W = 3
    p = small_problem()
    trace = make_trace("flaky-wan", p.profile, p.system, rounds=W + 1, seed=5)
    lat = p.cut_lattice()
    probe = [tuple(int(c) for c in lat[k]) for k in (0, len(lat) // 2)]

    win = windowed(p, trace, W, window=W)
    # warm the memoized scalar tables at the pre-wrap version
    for cuts in probe:
        win.split_T(cuts)
    before = win.split_T_batch(lat).copy()
    v0 = win.version
    assert win.n_obs == W

    win.push(trace.round_state(W))  # W+1-th push: first eviction
    assert win.version == v0 + 1
    assert win.n_obs == W  # buffer length did NOT move — only the version

    fresh = WindowedLatency(
        p.profile, p.system, lat, window=W, quantile=0.5
    )
    for r in range(1, W + 1):
        fresh.push(trace.round_state(r))
    states = list(win.states())
    mini = SystemTrace(
        "window", p.profile, p.system, W, 0, lambda r: states[r]
    )
    tl = TraceLatency(mini, quantile=0.5, backend="numpy")
    for cuts in probe:
        assert win.split_T(cuts) == fresh.split_T(cuts) == tl.split_T(cuts)
        for m in range(p.M - 1):
            assert (
                win.agg_T(cuts, m) == fresh.agg_T(cuts, m)
                == tl.agg_T(cuts, m)
            )
    # teeth: evicting round 0 really changed the priced tables somewhere —
    # serving the pre-wrap cache would be an observable bug
    assert not np.array_equal(before, win.split_T_batch(lat)), (
        "eviction left the whole split table unchanged; test is vacuous"
    )


def test_windowed_guards():
    p = small_problem()
    win = WindowedLatency(
        p.profile, p.system, p.cut_lattice(), window=4, quantile=0.5
    )
    with pytest.raises(ValueError, match="no observed rounds"):
        win.split_T(CUTS)
    with pytest.raises(ValueError, match="window must be"):
        WindowedLatency(p.profile, p.system, p.cut_lattice(), window=0)
    trace = make_trace("flaky-wan", p.profile, p.system, rounds=1, seed=0)
    win.push(trace.round_state(0))
    with pytest.raises(KeyError, match="not on the priced lattice"):
        win.split_T((0, 0))
    with pytest.raises(ValueError, match="lattice mismatch"):
        win.split_T_batch(p.cut_lattice()[:3])


# --------------------------------------------------------------------------- #
# telemetry
# --------------------------------------------------------------------------- #


def test_telemetry_reconstruction_roundtrip():
    """Windows priced from reconstructed telemetry match windows priced
    from ground-truth states to float round-off, and absent clients
    report NaN durations."""
    p = small_problem()
    trace = make_trace(
        "diurnal-churn", p.profile, p.system, rounds=8, seed=3, p_min=0.4
    )
    truth = WindowedLatency(
        p.profile, p.system, p.cut_lattice(), window=8, quantile=0.5
    )
    recon = WindowedLatency(
        p.profile, p.system, p.cut_lattice(), window=8, quantile=0.5
    )
    saw_absent = False
    for r in range(8):
        state = trace.round_state(r)
        obs = observe_round(trace, r, CUTS)
        absent = ~state.available
        if absent.any():
            saw_absent = True
            for d in obs.stage_durations:
                assert np.isnan(d[absent]).all()
        truth.push(state)
        recon.push(reconstruct_state(obs, p.profile, p.system))
    assert saw_absent, "scenario never dropped a client; test is vacuous"
    lat = p.cut_lattice()
    np.testing.assert_allclose(
        recon.split_T_batch(lat), truth.split_T_batch(lat), rtol=1e-9
    )
    np.testing.assert_allclose(
        recon.agg_T_batch(lat), truth.agg_T_batch(lat), rtol=1e-9
    )


def test_observation_carries_mask_and_loss():
    p = small_problem()
    trace = make_trace("flaky-wan", p.profile, p.system, rounds=2, seed=0)
    mask = np.zeros(p.system.num_clients, dtype=bool)
    mask[::2] = True
    obs = observe_round(trace, 0, CUTS, mask=mask, loss=1.5)
    assert obs.loss == 1.5
    np.testing.assert_array_equal(obs.mask, mask)
    win = WindowedLatency(
        p.profile, p.system, p.cut_lattice(), window=2, quantile=0.5
    )
    win.push(reconstruct_state(obs, p.profile, p.system), mask=obs.mask)
    q = win.q_tiers()
    assert q[0] == 0.5  # half the clients made the round
    assert q[-1] == 1.0  # the cloud tier always has its single entity


# --------------------------------------------------------------------------- #
# evaluator cache invalidation (the satellite bugfix)
# --------------------------------------------------------------------------- #


def test_evaluator_rebuilds_when_window_moves():
    p = small_problem()
    trace = make_trace("flaky-wan", p.profile, p.system, rounds=4, seed=1)
    win = windowed(p, trace, 2, window=4)
    wp = dataclasses.replace(p, latency_model=win)
    ev1 = wp.evaluator("numpy")
    assert wp.evaluator("numpy") is ev1  # stable version -> cached
    win.push(trace.round_state(2))
    ev2 = wp.evaluator("numpy")
    assert ev2 is not ev1  # version moved -> rebuilt
    assert wp.evaluator("numpy") is ev2
    wp.invalidate_caches()
    assert wp.evaluator("numpy") is not ev2  # explicit drop -> rebuilt


def test_evaluator_tables_track_the_window():
    """The rebuilt evaluator must price the *current* window — solving
    against a stale table is the bug the version token fixes."""
    p = small_problem()
    trace = make_trace("flaky-wan", p.profile, p.system, rounds=6, seed=1)
    win = windowed(p, trace, 3, window=3)
    wp = dataclasses.replace(p, latency_model=win)
    wp.evaluator("numpy")
    before = win.split_T_batch(p.cut_lattice()).copy()
    for r in range(3, 6):
        win.push(trace.round_state(r))
    after_tables = win.split_T_batch(p.cut_lattice())
    assert not np.array_equal(before, after_tables)
    ev = wp.evaluator("numpy")
    # the evaluator's pricing of the lattice matches the live window
    res_win = solve_bcd(wp, backend="numpy")
    fresh = dataclasses.replace(p, latency_model=win)
    res_fresh = solve_bcd(fresh, backend="numpy")
    assert (res_win.cuts, tuple(res_win.intervals)) == (
        res_fresh.cuts, tuple(res_fresh.intervals),
    )
    assert ev is wp.evaluator("numpy")


# --------------------------------------------------------------------------- #
# piecewise Theorem 1
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("schedule", [
    ((1, 1, 1), (3, 8), 10),
    ((4, 2, 1), (3, 8), 200),
    ((2, 5, 1), (5, 9), 1000),
])
def test_single_segment_collapses_bit_exact(schedule):
    intervals, cuts, R = schedule
    hp = synthetic_hyperspec(VGG.n_units, 8, seed=0)
    seg = BoundSegment(R, intervals, cuts)
    assert piecewise_bound(hp, [seg]) == theorem1_bound(hp, R, intervals, cuts)


def test_constant_schedule_split_matches_static():
    """Splitting a static run into segments at arbitrary points must not
    change the bound (same schedule everywhere)."""
    hp = synthetic_hyperspec(VGG.n_units, 8, seed=1)
    static = theorem1_bound(hp, 300, (4, 2, 1), CUTS)
    segs = [
        BoundSegment(120, (4, 2, 1), CUTS),
        BoundSegment(30, (4, 2, 1), CUTS),
        BoundSegment(150, (4, 2, 1), CUTS),
    ]
    np.testing.assert_allclose(piecewise_bound(hp, segs), static, rtol=1e-12)


def test_mixed_segments_interpolate_penalties():
    """The composed bound lies between the static bounds of its schedules
    (term1 is shared; term2+term3 is a convex combination)."""
    hp = synthetic_hyperspec(VGG.n_units, 8, seed=2)
    R = 400
    lo_sched, hi_sched = (1, 1, 1), (8, 4, 1)
    lo = theorem1_bound(hp, R, lo_sched, CUTS)
    hi = theorem1_bound(hp, R, hi_sched, CUTS)
    mixed = piecewise_bound(hp, [
        BoundSegment(250, lo_sched, CUTS),
        BoundSegment(150, hi_sched, CUTS),
    ])
    assert min(lo, hi) <= mixed <= max(lo, hi)
    with pytest.raises(ValueError, match="at least one segment"):
        piecewise_bound(hp, [])
    with pytest.raises(ValueError, match="positive"):
        BoundSegment(0, (1, 1, 1), CUTS)


def test_progress_ledger_reproduces_corollary1():
    """Constant per-round progress crosses the 2θ0/γ target at exactly
    Corollary 1's round count (static schedule, any participation)."""
    hp = synthetic_hyperspec(VGG.n_units, 8, seed=3)
    eps = theorem1_bound(hp, 500, (2, 2, 1), CUTS)
    for part in (None, 0.7):
        d = progress_per_round(hp, eps, (2, 2, 1), CUTS, participation=part)
        r_corollary = corollary1_rounds(
            hp, eps, (2, 2, 1), CUTS, participation=part
        )
        np.testing.assert_allclose(
            progress_target(hp) / d, r_corollary, rtol=1e-12
        )


# --------------------------------------------------------------------------- #
# warm re-solve == cold re-price + solve
# --------------------------------------------------------------------------- #


def test_warm_resolve_matches_cold_from_scratch():
    """The control-step path (memoized windowed tables + warm-seeded BCD) and
    the naive path (re-simulate the window into a TraceLatency, solve
    from the default anchor) find the identical optimum."""
    p = small_problem()
    trace = make_trace("flaky-wan", p.profile, p.system, rounds=8, seed=4)
    win = windowed(p, trace, 8)
    wp = dataclasses.replace(p, latency_model=win)
    anchor = solve_bcd(wp, backend="numpy")
    # warm-seed from a deliberately perturbed schedule
    init_i = tuple(max(1, i - 1) for i in anchor.intervals)
    warm = solve_bcd(
        wp, init_cuts=anchor.cuts, init_intervals=init_i,
        backend="numpy", warm_start=True,
    )
    states = list(win.states())
    mini = SystemTrace("window", p.profile, p.system, 8, 0, lambda r: states[r])
    cold = solve_bcd(
        dataclasses.replace(
            p, latency_model=TraceLatency(mini, quantile=0.5, backend="numpy")
        ),
        backend="numpy",
    )
    assert (warm.cuts, tuple(warm.intervals)) == (cold.cuts, tuple(cold.intervals))
    np.testing.assert_allclose(warm.theta, cold.theta, rtol=1e-12)


# --------------------------------------------------------------------------- #
# state migration
# --------------------------------------------------------------------------- #

N_MIG, U_MIG = 4, 6


def _stacked(key, N=N_MIG, U=U_MIG, d=4):
    ks = jax.random.split(key, 3)
    return {
        "frontend": {"embed": jax.random.normal(ks[0], (N, 8, d))},
        "units": {"w": jax.random.normal(ks[1], (N, U, d, d))},
        "head": {"norm": jax.random.normal(ks[2], (N, d))},
    }


def _client_mean(tree):
    return jax.tree.map(lambda x: np.asarray(jnp.mean(x, axis=0)), tree)


def _plan(cuts, intervals=(2, 2, 1)):
    return default_plan(
        U_MIG, N_MIG, cuts=cuts, intervals=intervals, entities=(N_MIG, 2, 1)
    )


def test_migrate_a_preserves_client_mean_and_is_idempotent():
    params = _stacked(jax.random.PRNGKey(0))
    new_plan = _plan((1, 4))
    out = migrate_params_a(params, new_plan)
    for a, b in zip(
        jax.tree.leaves(_client_mean(out)), jax.tree.leaves(_client_mean(params))
    ):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    # re-applying the same plan's consistency op changes nothing (group
    # sizes are powers of two, so the means are exact)
    again = migrate_params_a(out, new_plan)
    for a, b in zip(jax.tree.leaves(again), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adam"])
def test_migrate_a_carries_optimizer_moments(opt_name):
    from repro.core.engine import TrainState
    from repro.optim import adam, momentum, sgd

    opt = {"sgd": sgd, "momentum": momentum, "adam": adam}[opt_name](1e-2)
    params = _stacked(jax.random.PRNGKey(1))
    if opt_name == "sgd":
        opt_state = ()
    elif opt_name == "momentum":
        opt_state = _stacked(jax.random.PRNGKey(2))
    else:
        opt_state = {
            "m": _stacked(jax.random.PRNGKey(3)),
            "v": jax.tree.map(jnp.abs, _stacked(jax.random.PRNGKey(4))),
            "t": jnp.asarray(5, jnp.int32),
        }
    state = TrainState(params=params, opt_state=opt_state, step=7)
    new_plan = _plan((2, 3))
    out = migrate_state_a(state, new_plan, opt)
    assert out.step == 7
    for leaf_a, leaf_b in zip(
        jax.tree.leaves(out.params),
        jax.tree.leaves(migrate_params_a(params, new_plan)),
    ):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    if opt_name == "momentum":
        for leaf_a, leaf_b in zip(
            jax.tree.leaves(out.opt_state),
            jax.tree.leaves(migrate_params_a(opt_state, new_plan)),
        ):
            np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    elif opt_name == "adam":
        for key in ("m", "v"):
            for leaf_a, leaf_b in zip(
                jax.tree.leaves(out.opt_state[key]),
                jax.tree.leaves(migrate_params_a(opt_state[key], new_plan)),
            ):
                np.testing.assert_array_equal(
                    np.asarray(leaf_a), np.asarray(leaf_b)
                )
        assert int(out.opt_state["t"]) == 5  # step counter untouched
    else:
        assert out.opt_state == ()


def test_migrate_b_preserves_client_mean():
    from repro.core.engine import engine_b_to_full, init_state_b
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    spec = dataclasses.replace(
        VGG, conv_channels=(8, 16, 16), pool_after=(0, 1), fc_dims=(32, 10),
        name="vgg-tiny",
    )
    model = VggModel(spec)
    N = 4
    plan1 = default_plan(
        spec.n_units, N, cuts=(2, 3), intervals=(2, 1, 1), entities=(N, 2, 1)
    )
    plan2 = default_plan(
        spec.n_units, N, cuts=(1, 4), intervals=(1, 2, 1), entities=(N, 2, 1)
    )
    opt = sgd(1e-2)
    state = init_state_b(model, plan1, opt, jax.random.PRNGKey(0))
    migrated = migrate_state_b(state, model, plan1, plan2, opt)
    full_before = engine_b_to_full(model, plan1, state.params)
    full_after = engine_b_to_full(model, plan2, migrated.params)
    for a, b in zip(
        jax.tree.leaves(_client_mean(full_after)),
        jax.tree.leaves(_client_mean(full_before)),
    ):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    # dispatching wrapper demands the engine-b extras
    with pytest.raises(ValueError, match="model and old_plan"):
        migrate_state(state, plan2, opt, engine="b")


def test_resume_with_migration(tmp_path):
    from repro.checkpoint import save_checkpoint

    params = _stacked(jax.random.PRNGKey(5))
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, params, step=3, meta={"cuts": [1, 4]})
    same_plan = _plan((1, 4))
    tree, step, meta = resume_with_migration(path, params, same_plan)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a moved cut vector migrates instead of silently mis-partitioning
    moved_plan = _plan((2, 3))
    tree2, _, _ = resume_with_migration(path, params, moved_plan)
    expect = migrate_params_a(
        jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), params), moved_plan
    )
    for a, b in zip(jax.tree.leaves(tree2), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# --------------------------------------------------------------------------- #
# controller gating
# --------------------------------------------------------------------------- #


def _slowed(state, factor):
    return dataclasses.replace(
        state, compute_mult=tuple(c * factor for c in state.compute_mult)
    )


def test_controller_gates_and_drift_trigger():
    p = small_problem()
    res = solve_bcd(p, backend="numpy")
    trace = make_trace("homogeneous-paper", p.profile, p.system, rounds=16, seed=0)
    ctrl = Controller(
        p, res.cuts, res.intervals,
        window=4, min_window=4, cooldown=3, rel_tol=0.25, backend="numpy",
    )
    # 1) no-drift fast path: homogeneous telemetry matches nominal pricing
    for r in range(6):
        ctrl.observe(observe_round(trace, r, ctrl.cuts))
        assert ctrl.maybe_replan(r) is None
    assert ctrl.resolve_seconds == []  # the solver never ran
    assert ctrl.windowed_problem().participation is None  # full availability

    # 2) genuine drift (4x compute slowdown) fires exactly once, then the
    #    cooldown and the re-anchored snapshot keep the solver quiet
    slow = SystemTrace(
        "slow", p.profile, p.system, 16, 0,
        lambda r: _slowed(trace.round_state(r), 0.25),
    )
    dec = None
    for r in range(6, 12):
        ctrl.observe(observe_round(slow, r - 6, ctrl.cuts))
        got = ctrl.maybe_replan(r)
        if got is not None:
            dec = got
            break
    assert dec is not None and "latency" in dec.trigger
    assert dec.drift.split_rel > 0.25
    assert len(ctrl.resolve_seconds) == 1
    assert ctrl.decisions[-1] is dec
    for r in range(dec.round_index + 1, dec.round_index + 1 + ctrl.cooldown):
        ctrl.observe(observe_round(slow, r % 16, ctrl.cuts))
        assert ctrl.maybe_replan(r) is None  # cooldown window
    # post-cooldown the snapshot matches the window: still quiet
    r = dec.round_index + 1 + ctrl.cooldown
    ctrl.observe(observe_round(slow, r % 16, ctrl.cuts))
    assert ctrl.maybe_replan(r) is None


def test_controller_min_window_and_max_switches():
    p = small_problem()
    res = solve_bcd(p, backend="numpy")
    trace = make_trace("homogeneous-paper", p.profile, p.system, rounds=8, seed=0)
    slow = SystemTrace(
        "slow", p.profile, p.system, 8, 0,
        lambda r: _slowed(trace.round_state(r), 0.2),
    )
    ctrl = Controller(
        p, res.cuts, res.intervals,
        window=6, min_window=5, cooldown=0, rel_tol=0.25, backend="numpy",
        max_switches=1,
    )
    for r in range(4):  # drifted telemetry, but the window is too thin
        ctrl.observe(observe_round(slow, r, ctrl.cuts))
        assert ctrl.maybe_replan(r) is None
    ctrl.observe(observe_round(slow, 4, ctrl.cuts))
    assert ctrl.maybe_replan(4) is not None  # min_window reached -> fires
    # exhaust the switch budget: further drift must not re-solve
    ctrl._n_switches = ctrl.max_switches
    n_resolves = len(ctrl.resolve_seconds)
    fast = SystemTrace(
        "fast", p.profile, p.system, 8, 0,
        lambda r: _slowed(trace.round_state(r), 4.0),
    )
    for r in range(5, 8):
        ctrl.observe(observe_round(fast, r, ctrl.cuts))
        assert ctrl.maybe_replan(r) is None
    assert len(ctrl.resolve_seconds) == n_resolves


# --------------------------------------------------------------------------- #
# ControlCfg + the control run mode
# --------------------------------------------------------------------------- #


def test_controlcfg_validation_and_spec_roundtrip():
    import json

    from repro.api import ControlCfg, ExperimentSpec
    from repro.api.spec import RunCfg, ScenarioCfg, SolverCfg

    with pytest.raises(ValueError, match="window"):
        ControlCfg(window=1)
    with pytest.raises(ValueError, match="quantile"):
        ControlCfg(quantile=0.0)
    with pytest.raises(ValueError, match="rel_tol"):
        ControlCfg(rel_tol=0.0)
    with pytest.raises(ValueError, match="backend"):
        ControlCfg(backend="cuda")
    with pytest.raises(ValueError, match="mode"):
        RunCfg(mode="adapt")

    spec = ExperimentSpec(
        name="ctrl",
        scenario=ScenarioCfg(name="flaky-wan", rounds=8, quantile=0.5),
        solver=SolverCfg(kind="fixed", cuts=(3, 8), intervals=(4, 2, 1)),
        run=RunCfg(mode="control", rounds=4),
        control=ControlCfg(window=4, min_window=4, rel_tol=0.1, cooldown=2),
    )
    back = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec
    assert back.control == spec.control


def test_control_mode_requires_scenario():
    from repro.api import ControlCfg, ExperimentSpec, build
    from repro.api.spec import RunCfg, SolverCfg

    spec = ExperimentSpec(
        solver=SolverCfg(kind="fixed", cuts=(3, 8), intervals=(4, 2, 1)),
        run=RunCfg(mode="control", rounds=2),
        control=ControlCfg(),
    )
    with pytest.raises(ValueError, match="scenario"):
        build(spec)


@pytest.mark.slow
def test_control_mode_end_to_end():
    """run(mode="control") trains, observes, (maybe) switches, and emits a
    piecewise bound that collapses to the static bound when no switch
    fires; the result survives the JSON roundtrip."""
    import json

    from repro.api import (
        ControlCfg,
        ExperimentResult,
        ExperimentSpec,
        run,
    )
    from repro.api.spec import ModelCfg, RunCfg, ScenarioCfg, SolverCfg, SystemCfg

    spec = ExperimentSpec(
        name="control-smoke",
        model=ModelCfg(
            arch="smollm-135m", variant="reduced", num_layers=6, batch=4, seq=32
        ),
        system=SystemCfg(
            preset="paper-three-tier", num_clients=8, num_edges=4, seed=0
        ),
        scenario=ScenarioCfg(name="flaky-wan", rounds=16, seed=0, quantile=0.5),
        solver=SolverCfg(kind="fixed", cuts=(2, 4), intervals=(4, 2, 1)),
        run=RunCfg(mode="control", rounds=8, lr=0.1, log_every=0),
        control=ControlCfg(window=4, min_window=4, cooldown=2, rel_tol=0.05,
                           backend="numpy"),
    )
    res = run(spec)
    ctrl = res.control
    assert ctrl is not None
    assert ctrl["rounds"] == 8 and len(ctrl["losses"]) == 8
    assert np.isfinite(ctrl["final_loss"])
    assert ctrl["n_resolves"] >= ctrl["n_switches"] >= 0
    assert sum(s["rounds"] for s in ctrl["segments"]) == 8
    assert np.isfinite(ctrl["piecewise_bound"])
    if ctrl["n_switches"] == 0:
        assert ctrl["piecewise_bound"] == ctrl["static_bound"]
        assert len(ctrl["segments"]) == 1
    else:
        assert len(ctrl["switch_log"]) == ctrl["n_switches"]
    back = ExperimentResult.from_dict(json.loads(json.dumps(res.to_dict())))
    assert back.control["n_switches"] == ctrl["n_switches"]


# --------------------------------------------------------------------------- #
# the piecewise bound upper-envelopes a real migrated run
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_piecewise_bound_upper_envelopes_masked_run():
    """A real Engine-A masked training run that switches schedule mid-run:
    the measured average ||grad f(w_bar)||^2 sits below the piecewise
    Theorem-1 bound composed across the two segments (the bound_check
    methodology, plus a migration at the switch point)."""
    from repro.core import build_train_step_a, init_state_a
    from repro.core.estimator import HyperEstimator
    from repro.data import image_loader, make_cifar10_like, partition_iid
    from repro.models.vgg import VggModel
    from repro.optim import sgd

    spec = dataclasses.replace(
        VGG, conv_channels=(8, 16, 16), pool_after=(0, 1), fc_dims=(32, 10),
        name="vgg-tiny",
    )
    N, gamma, seed = 4, 0.01, 3
    r1, r2 = 8, 8
    q = 0.75  # 3 of 4 clients make every round
    sched1 = ((2, 3), (4, 1, 1))
    sched2 = ((1, 3), (2, 1, 1))
    ds = make_cifar10_like(256, noise=0.4, seed=seed)
    loader = image_loader(
        ds, partition_iid(len(ds), N, seed), batch=8, seed=seed
    )
    model = VggModel(spec)
    opt = sgd(gamma)
    eval_batch = {"images": jnp.asarray(ds.images[:192]),
                  "labels": jnp.asarray(ds.labels[:192])}
    gbar_fn = jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b))
    grad_fn = jax.jit(
        lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b)
    )

    def plan_of(sched):
        cuts, intervals = sched
        return default_plan(
            spec.n_units, N, cuts=cuts, intervals=intervals, entities=(N, 2, 1)
        )

    plan = plan_of(sched1)
    state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed))
    step = jax.jit(build_train_step_a(model, plan, opt, with_mask=True))
    est = HyperEstimator(spec.n_units, N, gamma)
    sq_norms = []
    for r in range(r1 + r2):
        if r == r1:  # the control switch: migrate, re-jit
            plan = plan_of(sched2)
            state = migrate_state(state, plan, opt, engine="a")
            step = jax.jit(build_train_step_a(model, plan, opt, with_mask=True))
        mask = np.ones(N, np.float32)
        mask[r % N] = 0.0  # rotating 3-of-4 participation
        batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
        losses, grads = grad_fn(state.params, batch)
        est.observe(state.params, grads, float(jnp.mean(losses)))
        wbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
        g = gbar_fn(wbar, eval_batch)
        sq_norms.append(float(sum(jnp.sum(x * x) for x in jax.tree.leaves(g))))
        state, _ = step(state, batch, jnp.asarray(mask))
    hp = est.hyperspec()
    measured = float(np.mean(sq_norms))
    bound = piecewise_bound(hp, [
        BoundSegment(r1, sched1[1], sched1[0], participation=q),
        BoundSegment(r2, sched2[1], sched2[0], participation=q),
    ])
    assert measured <= bound, (measured, bound)
    # and the composed bound is tighter than naively pricing the whole run
    # at the worst segment's penalty
    worst = max(
        theorem1_bound(hp, r1 + r2, s[1], s[0], participation=q)
        for s in (sched1, sched2)
    )
    assert bound <= worst + 1e-12
