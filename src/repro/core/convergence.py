"""Theorem 1 / Corollary 1 of the paper: the HSFL convergence bound.

All quantities are per-*unit* (our cut granularity) rather than per-layer;
this is exact when cut layers are restricted to unit boundaries, since only
tier-sums of G_l² enter the bound.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class HyperSpec:
    """Optimization constants of the bound (estimated or configured)."""
    gamma: float          # learning rate (paper: 5e-4)
    beta: float           # smoothness constant
    theta0: float         # f(w0) - f*
    num_clients: int      # N
    sigma2: np.ndarray    # per-unit gradient variance bounds   [U]
    G2: np.ndarray        # per-unit second-moment bounds       [U]

    @property
    def sigma2_sum(self) -> float:
        return float(np.sum(self.sigma2))


def tier_G2_sums(G2: np.ndarray, cuts: Sequence[int]) -> np.ndarray:
    """Σ_{l in tier m} G_l² for every tier (M = len(cuts)+1).

    Computed as leading-zero cumsum differences — the canonical tier-sum
    arithmetic shared with the batched lattice core
    (``core.batched.tier_d_lattice``), so scalar and batched d_m agree
    bit-for-bit.
    """
    bounds = [0, *cuts, len(G2)]
    cs = np.concatenate(([0.0], np.cumsum(np.asarray(G2, dtype=np.float64))))
    return np.array(
        [float(cs[bounds[m + 1]] - cs[bounds[m]]) for m in range(len(bounds) - 1)]
    )


def theorem1_bound(
    hp: HyperSpec,
    R: int,
    intervals: Sequence[int],
    cuts: Sequence[int],
    omega: float = 0.0,
) -> float:
    """RHS of Eq. (8): bound on (1/R) Σ_t E||∇f||².

    ``omega`` is the compression-error second moment ω of a lossy
    aggregation wire (DESIGN.md §9): an unbiased codec with
    E‖C(g) − g‖² ≤ ω‖g‖² inflates the stochastic-gradient variance term
    to (1 + ω)σ², leaving the drift term untouched.  ω = 0 recovers the
    paper's full-precision bound exactly.
    """
    g, b = hp.gamma, hp.beta
    d = tier_G2_sums(hp.G2, cuts)
    term1 = 2.0 * hp.theta0 / (g * R)
    term2 = b * g * (1.0 + omega) * hp.sigma2_sum / hp.num_clients
    term3 = 4.0 * b**2 * g**2 * sum(
        (I**2) * dm for I, dm in zip(intervals[:-1], d[:-1]) if I > 1
    )
    return term1 + term2 + term3


def corollary1_rounds(
    hp: HyperSpec,
    eps: float,
    intervals: Sequence[int],
    cuts: Sequence[int],
    omega: float = 0.0,
) -> Optional[float]:
    """Eq. (10): rounds to reach target ε; None if the schedule cannot reach ε."""
    g, b = hp.gamma, hp.beta
    d = tier_G2_sums(hp.G2, cuts)
    denom = eps - b * g * (1.0 + omega) * hp.sigma2_sum / hp.num_clients
    denom -= 4.0 * b**2 * g**2 * sum(
        (I**2) * dm for I, dm in zip(intervals[:-1], d[:-1]) if I > 1
    )
    if denom <= 0:
        return None
    return 2.0 * hp.theta0 / (g * denom)


def bound_constants(
    hp: HyperSpec, eps: float, omega: float = 0.0
) -> Tuple[float, float]:
    """(c, kappa) with denominator = c - kappa * Σ 1{I>1} I² d_m  (Eq. 22/24).

    ω shrinks c (the ε headroom left after the (1+ω)-inflated variance
    term), which is how compression noise reaches the MA/MS solvers.
    """
    c = eps - hp.beta * hp.gamma * (1.0 + omega) * hp.sigma2_sum / hp.num_clients
    kappa = 4.0 * hp.beta**2 * hp.gamma**2
    return c, kappa


def synthetic_hyperspec(
    n_units: int,
    num_clients: int,
    gamma: float = 5e-4,
    beta: float = 50.0,
    theta0: float = 5.0,
    g2_scale: float = 20.0,
    sigma2_scale: float = 4.0,
    decay: float = 0.9,
    seed: int = 0,
) -> HyperSpec:
    """Plausible per-unit G²/σ² profile (earlier layers larger, as in CNN/LLM
    practice); used where no estimation run is available."""
    rng = np.random.default_rng(seed)
    prof = decay ** np.arange(n_units)
    jitter = rng.uniform(0.8, 1.2, n_units)
    return HyperSpec(
        gamma=gamma,
        beta=beta,
        theta0=theta0,
        num_clients=num_clients,
        sigma2=sigma2_scale * prof * jitter,
        G2=g2_scale * prof * jitter,
    )
