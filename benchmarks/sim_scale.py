"""sim_scale: fleet-simulator throughput vs fleet size, to 10⁶ clients.

Two parts:

* oracle cross-check — for every scenario in the library, the discrete-event
  core and the vectorized fast path must agree *bit-exactly* at N ≤ 256
  (the contract ``tests/test_sim.py`` enforces; re-asserted here so the
  benchmark never reports throughput for a path that drifted);
* scale sweep — rounds/sec and client·rounds/sec of the vectorized path for
  N = 10³ … 10⁶ on the straggler-tail scenario (per-round PRNG draws + the
  full stage chain, i.e. the most work per round).
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import ModelCfg, SystemCfg, resolve_model, resolve_system
from repro.core import SystemSpec, build_profile
from repro.sim import SCENARIOS, make_trace, simulate, simulate_rounds

from .common import emit

CUTS = (3, 8)
INTERVALS = (2, 4, 1)


def big_system(n: int, seed: int) -> SystemSpec:
    return resolve_system(
        SystemCfg(
            preset="paper-three-tier",
            num_clients=n,
            num_edges=max(1, n // 200),
            seed=seed,
        )
    )


def main(quick: bool = False, seed: int = 0) -> list:
    prof = build_profile(resolve_model(ModelCfg(arch="vgg16-cifar10")), batch=16)
    rows = []

    # --- event-core oracle vs vectorized path, all scenarios, N <= 256 ----
    for n in (64, 256):
        system = big_system(n, seed)
        for name in sorted(SCENARIOS):
            trace = make_trace(name, prof, system, rounds=4, seed=seed)
            ev = simulate(trace, CUTS, INTERVALS)
            fl = simulate_rounds(trace, CUTS, INTERVALS)
            exact = bool(
                np.array_equal(ev.split, fl.split)
                and np.array_equal(ev.agg, fl.agg)
                and np.array_equal(ev.total, fl.total)
            )
            assert exact, f"oracle mismatch: {name} at N={n}"
            rows.append(("oracle_check", name, n, 4, 0.0, float(exact)))

    # --- vectorized throughput sweep --------------------------------------
    # The warm pass generates + caches every round's PRNG state and warms the
    # jnp dispatch, so the timed pass measures the fast-path arithmetic alone
    # (trace generation is a one-time cost per round, amortized on replay).
    sweep = [1_000, 10_000, 100_000] + ([] if quick else [1_000_000])
    rounds = 4
    for n in sweep:
        system = big_system(n, seed)
        trace = make_trace("straggler-tail", prof, system, rounds=rounds, seed=seed)
        t0 = time.perf_counter()
        simulate_rounds(trace, CUTS, INTERVALS)  # generation + fast path
        gen_dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = simulate_rounds(trace, CUTS, INTERVALS)  # fast path only
        dt = time.perf_counter() - t0
        rows.append(("scale_sweep_cold", "straggler-tail", n, rounds, gen_dt,
                     n * rounds / gen_dt))
        rows.append(("scale_sweep", "straggler-tail", n, rounds, dt,
                     n * rounds / dt))
        assert (res.participants > 0).all()

    emit(rows, ("part", "scenario", "clients", "rounds", "seconds",
                "client_rounds_per_s"))
    if not quick:  # the headline: a million-client round via the fast path
        assert max(r[2] for r in rows if r[0] == "scale_sweep") >= 1_000_000
    return rows


if __name__ == "__main__":
    main()
