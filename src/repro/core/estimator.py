"""Estimation of the convergence-bound constants (β, σ_l², G_l², ϑ).

Follows the approach of Wang et al. [28] (as cited in Sec. VI): the constants
are estimated from a short probe run of the actual training system —

* G_l²  : running mean of per-unit squared gradient norms (per client),
* σ_l²  : running mean of the per-unit across-client variance of the
          stochastic gradients (unbiased per Assumption 2's structure),
* β     : max ratio ‖∇̄f(w_t) − ∇̄f(w_{t-1})‖ / ‖w_t − w_{t-1}‖ over probe
          steps (a smoothness lower-envelope estimate),
* ϑ     : f(w_0) − f̂* with f̂* the best loss seen (refined as training runs).

All quantities are computed on the client-stacked Engine-A layout, so the
estimator can run inside the production training loop at negligible cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .convergence import HyperSpec

Params = Dict[str, Any]


def _unit_sq_norms(tree: Params, n_units: int) -> jax.Array:
    """Per-unit squared norms of a (client-stacked) pytree: returns [N, U].

    ``frontend`` folds into unit 0 and ``head`` into unit U−1, mirroring the
    paper's convention that cut layers never separate the embedding from the
    first block nor the head from the last.
    """
    units = tree["units"]

    def stack_sq(t) -> jax.Array:  # [N, U]
        leaves = jax.tree.leaves(t)
        tot = None
        for x in leaves:
            s = jnp.sum(
                jnp.square(x.astype(jnp.float32)), axis=tuple(range(2, x.ndim))
            )
            tot = s if tot is None else tot + s
        return tot

    if isinstance(units, (list, tuple)):
        per = [
            sum(
                jnp.sum(jnp.square(x.astype(jnp.float32)), axis=tuple(range(1, x.ndim)))
                for x in jax.tree.leaves(u)
            )
            for u in units
        ]
        sq = jnp.stack(per, axis=1)  # [N, U]
    elif isinstance(units, dict) and set(units) == {"enc", "dec"}:
        sq = jnp.concatenate([stack_sq(units["enc"]), stack_sq(units["dec"])], axis=1)
    else:
        sq = stack_sq(units)
    assert sq.shape[1] == n_units, (sq.shape, n_units)

    def extra_sq(part) -> jax.Array:  # [N]
        if part is None or not jax.tree.leaves(part):
            return jnp.zeros(sq.shape[0], jnp.float32)
        return sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)), axis=tuple(range(1, x.ndim)))
            for x in jax.tree.leaves(part)
        )

    sq = sq.at[:, 0].add(extra_sq(tree.get("frontend")))
    sq = sq.at[:, -1].add(extra_sq(tree.get("head")))
    return sq


def _unit_sq_norms_mean_tree(tree: Params, n_units: int) -> jax.Array:
    """[U] squared norms of a non-stacked tree (client axis already reduced)."""
    stacked = jax.tree.map(lambda x: x[None], tree)
    return _unit_sq_norms(stacked, n_units)[0]


def _global_sq_norm(tree) -> jax.Array:
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))


@dataclass
class HyperEstimator:
    """Accumulates probe-run statistics into a HyperSpec.

    ``window=None`` (the default) keeps running sums over the whole probe —
    the offline estimation mode.  ``window=W`` keeps only the last W
    observations in ring buffers, which is the online mode the adaptive
    controller (``repro.control``) consumes: the emitted ``HyperSpec``
    tracks the *current* regime instead of a lifetime average, and stale
    rounds age out as the window wraps.
    """

    n_units: int
    num_clients: int
    gamma: float
    window: Optional[int] = None

    def __post_init__(self):
        if self.window is not None and self.window < 2:
            raise ValueError(
                f"window must be >= 2 (beta needs consecutive observations), "
                f"got {self.window}"
            )
        self._g2_sum = np.zeros(self.n_units)
        self._var_sum = np.zeros(self.n_units)
        self._steps = 0
        self._beta = 0.0
        self._prev_mean_grad: Optional[Params] = None
        self._prev_params: Optional[Params] = None
        self._f0: Optional[float] = None
        self._fmin = float("inf")
        if self.window is not None:
            from collections import deque

            self._g2_hist = deque(maxlen=self.window)    # [U] per round
            self._var_hist = deque(maxlen=self.window)   # [U] per round
            self._beta_hist = deque(maxlen=self.window)  # ratio or None
            self._loss_hist = deque(maxlen=self.window)  # float

    # ------------------------------------------------------------------ #
    def observe(self, params: Params, grads: Params, loss: float) -> None:
        """Feed one probe round: client-stacked params/grads + mean loss."""
        sq = np.asarray(_unit_sq_norms(grads, self.n_units))  # [N, U]
        g2_round = sq.mean(axis=0)
        self._g2_sum += g2_round
        mean_grad = jax.tree.map(
            lambda g: jnp.mean(g.astype(jnp.float32), axis=0, keepdims=True), grads
        )
        # Var_n[g] per unit = E_n ||g_n||² − ||ḡ||² (per-unit decomposition)
        mean_sq = np.asarray(_unit_sq_norms(mean_grad, self.n_units))[0]
        var_round = np.maximum(g2_round - mean_sq, 0.0)
        self._var_sum += var_round
        ratio: Optional[float] = None
        if self._prev_mean_grad is not None:
            dg = jax.tree.map(
                lambda a, b: a - b, mean_grad, self._prev_mean_grad
            )
            dw = jax.tree.map(lambda a, b: a - b, params, self._prev_params)
            num = float(jnp.sqrt(_global_sq_norm(dg)))
            den = float(jnp.sqrt(_global_sq_norm(dw)))
            if den > 1e-12:
                ratio = num / den
                self._beta = max(self._beta, ratio)
        self._prev_mean_grad = mean_grad
        self._prev_params = jax.tree.map(lambda x: x, params)
        loss = float(loss)
        if self._f0 is None:
            self._f0 = loss
        self._fmin = min(self._fmin, loss)
        self._steps += 1
        if self.window is not None:
            self._g2_hist.append(g2_round)
            self._var_hist.append(var_round)
            self._beta_hist.append(ratio)
            self._loss_hist.append(loss)

    # ------------------------------------------------------------------ #
    def hyperspec(self, fstar_margin: float = 0.5) -> HyperSpec:
        if self._steps == 0:
            raise ValueError("no probe rounds observed")
        if self.window is not None:
            G2 = np.mean(np.stack(tuple(self._g2_hist)), axis=0)
            sigma2 = np.mean(np.stack(tuple(self._var_hist)), axis=0)
            ratios = [b for b in self._beta_hist if b is not None]
            beta = max(max(ratios, default=0.0), 1e-3)
            f0 = self._loss_hist[0]
            theta0 = max(f0 - min(self._loss_hist), fstar_margin * f0, 1e-3)
            return HyperSpec(
                gamma=self.gamma,
                beta=beta,
                theta0=float(theta0),
                num_clients=self.num_clients,
                sigma2=sigma2,
                G2=G2,
            )
        G2 = self._g2_sum / self._steps
        sigma2 = self._var_sum / self._steps
        theta0 = max(self._f0 - self._fmin, fstar_margin * self._f0, 1e-3)
        beta = max(self._beta, 1e-3)
        return HyperSpec(
            gamma=self.gamma,
            beta=beta,
            theta0=float(theta0),
            num_clients=self.num_clients,
            sigma2=sigma2,
            G2=G2,
        )


def estimate_from_probe(
    model,
    plan,
    opt,
    batches: Iterable[Params],
    key,
    gamma: float,
) -> HyperSpec:
    """Convenience: run Engine A for the probe batches and estimate."""
    from .engine import build_train_step_a, init_state_a

    state = init_state_a(model, plan, opt, key)
    est = HyperEstimator(plan.n_units, plan.num_clients, gamma)

    grad_fn = jax.jit(
        lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b)
    )
    step = jax.jit(build_train_step_a(model, plan, opt))
    for batch in batches:
        losses, grads = grad_fn(state.params, batch)
        est.observe(state.params, grads, float(jnp.mean(losses)))
        state, _ = step(state, batch)
    return est.hyperspec()
