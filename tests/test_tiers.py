"""TierPlan + synchronize: the HSFL aggregation schedule (Eqs. 3-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiers import TierPlan, default_plan, synchronize, tier_subtrees, combine_tiers


def _params(key, N, U, d=4):
    ks = jax.random.split(key, 3)
    return {
        "frontend": {"embed": jax.random.normal(ks[0], (N, 8, d))},
        "units": {"w": jax.random.normal(ks[1], (N, U, d, d))},
        "head": {"norm": jax.random.normal(ks[2], (N, d))},
    }


def test_plan_validation():
    # user-facing invariants raise ValueError (asserts would vanish under
    # ``python -O`` — see test_plan_validation_without_assertions)
    with pytest.raises(ValueError, match="non-decreasing"):
        TierPlan(8, 8, cuts=(5, 3), intervals=(2, 2, 1), entities=(8, 4, 1))
    with pytest.raises(ValueError, match="intervals"):
        TierPlan(8, 8, cuts=(2, 4), intervals=(2, 2, 2), entities=(8, 4, 1))
    with pytest.raises(ValueError, match="evenly divide"):
        TierPlan(8, 8, cuts=(2, 4), intervals=(2, 2, 1), entities=(8, 3, 1))
    with pytest.raises(ValueError, match="cuts"):
        TierPlan(8, 8, cuts=(2,), intervals=(2, 2, 1), entities=(8, 4, 1))
    with pytest.raises(ValueError, match="n_units"):
        TierPlan(8, 8, cuts=(2, 9), intervals=(2, 2, 1), entities=(8, 4, 1))
    with pytest.raises(ValueError, match="tiers"):
        TierPlan(8, 8, cuts=(2, 4), intervals=(2, 2, 1), entities=(8, 1))


def test_plan_validation_without_assertions():
    """Invalid plans must still raise under ``python -O`` (bare asserts are
    stripped by the optimizer; the invariants are ValueError-backed)."""
    import subprocess
    import sys

    code = (
        "from repro.core.tiers import TierPlan\n"
        "for bad in [\n"
        "    dict(cuts=(5, 3), intervals=(2, 2, 1), entities=(8, 4, 1)),\n"
        "    dict(cuts=(2, 4), intervals=(2, 2, 2), entities=(8, 4, 1)),\n"
        "    dict(cuts=(2, 4), intervals=(2, 2, 1), entities=(8, 3, 1)),\n"
        "]:\n"
        "    try:\n"
        "        TierPlan(8, 8, **bad)\n"
        "    except ValueError:\n"
        "        pass\n"
        "    else:\n"
        "        raise SystemExit(f'invalid plan accepted under -O: {bad}')\n"
        "print('ok')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-O", "-c", code],
        capture_output=True, text=True,
        env={**__import__("os").environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr
    assert "ok" in proc.stdout


def test_tier_bounds_cover():
    plan = default_plan(10, 8, cuts=(2, 6))
    bounds = [plan.tier_bounds(m) for m in range(plan.M)]
    assert bounds == [(0, 2), (2, 6), (6, 10)]
    for u in range(10):
        m = plan.tier_of_unit(u)
        lo, hi = plan.tier_bounds(m)
        assert lo <= u < hi


def test_subtrees_roundtrip():
    N, U = 8, 10
    params = _params(jax.random.PRNGKey(0), N, U)
    plan = default_plan(U, N, cuts=(3, 7))
    parts = tier_subtrees(params, plan)
    assert parts[0]["units"]["w"].shape == (N, 3, 4, 4)
    assert parts[1]["units"]["w"].shape == (N, 4, 4, 4)
    back = combine_tiers(parts, params)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("seed", range(5))
def test_synchronize_entity_level_every_round(seed):
    """Eq. 3: sub-models co-hosted by an entity are identical every round."""
    N, U = 8, 6
    params = _params(jax.random.PRNGKey(seed), N, U)
    plan = default_plan(U, N, cuts=(2, 4), intervals=(5, 3, 1), entities=(N, 4, 1))
    out = synchronize(params, plan, jnp.int32(0))  # step 0: no global for I>1
    w = out["units"]["w"]
    # tier 2 (units 2..4) entity groups of 2 clients are equal
    for g in range(4):
        np.testing.assert_allclose(w[2 * g, 2:4], w[2 * g + 1, 2:4], rtol=1e-6)
    # tier 3 (units 4..6) globally equal (cloud server, I=1)
    for n in range(1, N):
        np.testing.assert_allclose(w[0, 4:], w[n, 4:], rtol=1e-6)
    # tier 1 (units 0..2) untouched at step 0 (J_1 = N, I_1 = 5)
    assert not np.allclose(w[0, 0], w[1, 0])


@pytest.mark.parametrize("interval", [2, 3, 4])
def test_synchronize_interval_trigger(interval):
    """Eq. 4 fires exactly when (step+1) % I == 0."""
    N, U = 4, 4
    params = _params(jax.random.PRNGKey(1), N, U)
    plan = default_plan(
        U, N, cuts=(2,), intervals=(interval, 1), entities=(N, 1)
    )
    for step in range(6):
        out = synchronize(params, plan, jnp.int32(step))
        w = out["units"]["w"]
        synced = np.allclose(w[0, :2], w[1, :2])
        assert synced == (((step + 1) % interval) == 0), step


def test_synchronize_means_are_exact():
    N, U = 6, 3
    params = _params(jax.random.PRNGKey(2), N, U)
    # tier 1: global at I=1; tier 2: entity-only at step 0 (I=5 not due)
    plan = default_plan(U, N, cuts=(1, 2), intervals=(1, 5, 1), entities=(N, 3, 1))
    out = synchronize(params, plan, jnp.int32(0))
    w_in = params["units"]["w"]
    w = out["units"]["w"]
    np.testing.assert_allclose(
        w[:, 0], np.broadcast_to(w_in[:, 0].mean(0), w_in[:, 0].shape), rtol=1e-5
    )
    np.testing.assert_allclose(
        w[0, 1], w_in[[0, 1], 1].mean(0), rtol=1e-5
    )  # entity group {0,1} of tier 2


def test_pod_level_schedule():
    """Multi-pod: top tier is per-pod every round, cross-pod at pod_interval."""
    N, U = 8, 2
    params = _params(jax.random.PRNGKey(3), N, U)
    plan = TierPlan(
        n_units=U, num_clients=N, cuts=(1,), intervals=(1, 1),
        entities=(N, 1), num_pods=2, pod_interval=3,
    )
    out0 = synchronize(params, plan, jnp.int32(0))
    w = out0["units"]["w"]
    # per-pod mean on tier 2: pods {0..3}, {4..7} internally equal but differ
    np.testing.assert_allclose(w[0, 1:], w[3, 1:], rtol=1e-6)
    assert not np.allclose(w[0, 1:], w[4, 1:])
    out2 = synchronize(params, plan, jnp.int32(2))  # (2+1) % 3 == 0
    w2 = out2["units"]["w"]
    np.testing.assert_allclose(w2[0, 1:], w2[7, 1:], rtol=1e-6)


def _lossy(x):
    """A visibly lossy wire transform (round to a 1/4 grid)."""
    return jnp.round(x * 4.0) / 4.0


@pytest.mark.parametrize("step", [0, 1])
def test_sync_allones_mask_with_compression_matches_unmasked(step):
    """An all-ones mask composed with a lossy fed wire is bit-identical to
    the unmasked compressed path (DESIGN.md §9 + §12 compose exactly)."""
    N, U = 8, 6
    params = _params(jax.random.PRNGKey(11), N, U)
    plan = default_plan(U, N, cuts=(2, 4), intervals=(1, 2, 1),
                        entities=(N, 4, 1))
    ref = synchronize(params, plan, jnp.int32(step), compress_fn=_lossy)
    out = synchronize(params, plan, jnp.int32(step), compress_fn=_lossy,
                      mask=jnp.ones((N,), jnp.float32))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sync_zero_participant_group_keeps_exact_params():
    """A zero-participant entity group keeps its members' *exact* current
    params. Nothing was uploaded, so nothing may move — not even through
    the lossy fed wire (the silent group must not 'keep' a lossy-coded
    copy it never sent)."""
    N, U = 8, 6
    params = _params(jax.random.PRNGKey(12), N, U)
    # tier 2 fed level at I=3 does not fire at step 0, so tier 2 is
    # entity-level only this round; tier 1 (client units) feds every round.
    plan = default_plan(U, N, cuts=(2, 4), intervals=(1, 3, 1),
                        entities=(N, 4, 1))
    mask = jnp.ones((N,), jnp.float32).at[0].set(0.0).at[1].set(0.0)
    out = synchronize(params, plan, jnp.int32(0), compress_fn=_lossy,
                      mask=mask)
    w_in = np.asarray(params["units"]["w"])
    w = np.asarray(out["units"]["w"])
    # entity group {0,1} of tier 2 (units 2..4) has zero participants:
    # bit-exact hold of the pre-sync params
    np.testing.assert_array_equal(w[:2, 2:4], w_in[:2, 2:4])
    # a participating group averages its participants (uncompressed Eq. 3)
    np.testing.assert_allclose(
        w[2, 2:4], w_in[2:4, 2:4].mean(0), rtol=1e-6
    )
    # the silent clients still *receive* levels whose group has
    # participants (state lives at the server): tier-1 fed mean moved them
    assert not np.array_equal(w[:2, :2], w_in[:2, :2])


def test_sync_fully_masked_round_is_identity_despite_compression():
    """With no participants anywhere, synchronize is a bit-exact identity
    even though the lossy fed transform runs inside the graph — the
    zero-participant fallback must be the pre-compression tree."""
    N, U = 8, 6
    params = _params(jax.random.PRNGKey(13), N, U)
    plan = default_plan(U, N, cuts=(2, 4), intervals=(1, 1, 1),
                        entities=(N, 4, 1))
    out = synchronize(params, plan, jnp.int32(0), compress_fn=_lossy,
                      mask=jnp.zeros((N,), jnp.float32))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # teeth: the same round with full participation is NOT an identity
    # (the wire really is lossy)
    moved = synchronize(params, plan, jnp.int32(0), compress_fn=_lossy,
                        mask=jnp.ones((N,), jnp.float32))
    assert not np.array_equal(np.asarray(moved["units"]["w"]),
                              np.asarray(params["units"]["w"]))


@pytest.mark.parametrize("step", [0, 1, 3, 7])
def test_round_specialization_matches_dynamic(step):
    """fed_round=True/False specialized steps == the dynamic cond schedule.

    The production dispatch `sync if (t+1) % I == 0 else local` must produce
    bit-identical params to the single dynamic step at every round.
    """
    N, U = 8, 4
    params = _params(jax.random.PRNGKey(7), N, U)
    plan = default_plan(U, N, cuts=(1, 3), intervals=(4, 2, 1),
                        entities=(N, 4, 1))
    dyn = synchronize(params, plan, jnp.int32(step))
    # production dispatch: per-tier round-type tuple
    fed = tuple((step + 1) % I == 0 for I in plan.intervals)
    spec = synchronize(params, plan, jnp.int32(step), fed_round=fed)
    for d_leaf, s_leaf in zip(jax.tree.leaves(dyn), jax.tree.leaves(spec)):
        np.testing.assert_allclose(np.asarray(d_leaf), np.asarray(s_leaf),
                                   rtol=0, atol=0)
