"""System optimization demo: the paper's Sec. V-VI pipeline end to end.

Builds the exact Sec. VII client(20)-edge(5)-cloud(1) system with VGG-16,
solves the joint MA+MS problem with the BCD algorithm (Algorithm 2:
Proposition-1 Newton-Jacobi MA solver + Dinkelbach MILFP MS solver), and
compares the optimized schedule against the paper's random baselines.

Also prices the same model on the TPU-pod mapping (DESIGN.md sect. 2) to
show the optimizer adapts (I, mu) to a completely different link hierarchy.

    PYTHONPATH=src python examples/optimize_system.py
"""
import numpy as np

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem, SystemSpec, build_profile, solve_bcd, solve_ma,
    synthetic_hyperspec,
)


def describe(tag, prob, res):
    R = prob.rounds(res.intervals, res.cuts)
    print(f"{tag:>14s}: cuts={res.cuts} I={tuple(res.intervals)} "
          f"Theta'={res.theta:.4g}  R_to_eps={R:.0f}  T={res.total_latency:.1f}s")


def random_schedule_theta(prob, rng, n=200):
    """RMA+RMS baseline: expected Theta' over random (I, mu) draws."""
    thetas = []
    for _ in range(n):
        cuts = tuple(sorted(rng.integers(3, 15, size=2)))
        I = (int(rng.integers(1, 26)), int(rng.integers(1, 26)), 1)
        th = prob.theta(I, cuts)
        if np.isfinite(th):
            thetas.append(th)
    return float(np.median(thetas))


def main():
    # per-unit FLOPs / activation / parameter profile of VGG-16 at b=16
    prof = build_profile(VGG, batch=16)
    hp = synthetic_hyperspec(VGG.n_units, num_clients=20, seed=0)

    # --- the paper's WAN system (Sec. VII numbers) ----------------------
    system = SystemSpec.paper_three_tier(num_clients=20, num_edges=5, seed=0)
    prob = HsflProblem(prof, system, hp, eps=2.0)
    res = solve_bcd(prob)
    describe("BCD (paper)", prob, res)
    rng = np.random.default_rng(0)
    rand = random_schedule_theta(prob, rng)
    print(f"{'RMA+RMS':>14s}: median Theta' {rand:.4g}  "
          f"-> BCD speedup {rand / res.theta:.1f}x")

    # --- the TPU-pod mapping: same model, ICI/DCN link prices -----------
    tpu = SystemSpec.tpu_pod_mapping(num_clients=16, num_edges=4)
    prof16 = build_profile(VGG, batch=16)
    hp16 = synthetic_hyperspec(VGG.n_units, num_clients=16, seed=0)
    prob_tpu = HsflProblem(prof16, tpu, hp16, eps=2.0)
    res_tpu = solve_bcd(prob_tpu)
    describe("BCD (TPU pod)", prob_tpu, res_tpu)
    print("note: faster links -> the optimizer picks smaller I_m "
          "(aggregate more often) and moves the cut shallower")

    # --- Proposition 1 (MA sub-problem) on a fixed deep cut -------------
    # deeper cuts put big fc layers in low tiers -> expensive aggregation
    # -> the optimal I_m grows exactly as the paper's Insight predicts
    print("\nProposition-1 MA solver, fixed cuts (Insight after Eq. 37):")
    for cuts in [(2, 4), (5, 10), (8, 13)]:
        sol = solve_ma(prob, cuts)
        print(f"  cuts={cuts}: agg T_m,A={prob.agg_T(cuts).round(2)}s "
              f"-> I*={tuple(sol.intervals)}")

    # --- resource-scaling robustness (paper Fig. 6 trend) ---------------
    print("\ncomm-scaling sweep (paper Fig. 6):")
    for scale in (1.0, 0.5, 0.25):
        s = SystemSpec.paper_three_tier(20, 5, seed=0, comm_scale=scale)
        p = HsflProblem(prof, s, hp, eps=2.0)
        r = solve_bcd(p)
        print(f"  comm x{scale:>4}: Theta'={r.theta:.4g} I={tuple(r.intervals)} "
              f"cuts={r.cuts}")


if __name__ == "__main__":
    main()
