# repro.api — the declarative driver layer (DESIGN.md §10).
#
# One serializable ExperimentSpec describes model / system / scenario /
# compression / solver / run; build() composes the underlying repro.core
# objects in the one valid order; run() dispatches to the BCD/MA/MS
# solvers, the fleet simulator, or Engine A/B training and returns a
# uniform ExperimentResult whose provenance is the resolved spec.
from .spec import (
    ClassesCfg,
    CompressionCfg,
    ControlCfg,
    EnergyCfg,
    ExperimentSpec,
    FaultsCfg,
    HyperCfg,
    ModelCfg,
    ParticipationCfg,
    PrivacyCfg,
    RunCfg,
    ScenarioCfg,
    SolverCfg,
    SystemCfg,
)
from .registry import (
    CODECS,
    MODEL_IDS,
    SYSTEMS,
    register_codec,
    register_system,
    resolve_model,
    resolve_system,
    scenario_names,
)
from .build import BuiltExperiment, build, resolve_compression
from .result import ExperimentResult, jsonify
from .run import evaluate_schedule, run
from .presets import (
    EXPERIMENTS,
    compressed_spec,
    fault_storm_spec,
    get_experiment,
    hetcuts_spec,
    paper_spec,
    participation_spec,
    privacy_energy_spec,
    quickstart_spec,
    register_experiment,
    robust_spec,
    tpu_pod_spec,
    two_tier_spec,
)
