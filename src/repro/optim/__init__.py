from .optimizers import Optimizer, sgd, momentum, adam, opt_state_bytes_per_param

__all__ = ["Optimizer", "sgd", "momentum", "adam", "opt_state_bytes_per_param"]
