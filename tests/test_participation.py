"""Partial participation (DESIGN.md §12): mask-weighted aggregation,
deadline masks / q_m estimation, expectation pricing, bound inflation.

Contracts pinned here:

* mask-weighted ``tiers.synchronize``: an all-ones mask is BIT-EXACT with
  the unmasked path, per-group weights sum to 1, client order within an
  entity doesn't matter, and a zero-participant group keeps its last
  synced params;
* ``participation_masks`` / ``deadline_for_rate`` / ``estimate_participation``
  semantics, including the effective-deadline (≥ 1 participant) rule;
* ``DeadlineLatency`` scalar protocol == whole-lattice batch methods,
  bit-for-bit, and solver optima identical across backends;
* the 1/q Theorem-1 inflation: q ≡ 1 is bit-identical to the plain bound,
  the bound is monotone in q, and scalar/batched denominators agree;
* the zero-participant-round convention: one documented behavior across
  the event oracle, the fleet fast path, the lattice path, the deadline
  pricing, and the new mask path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg16_cifar10 import SPEC as VGG
from repro.core import (
    HsflProblem,
    ParticipationSpec,
    SystemSpec,
    build_profile,
    solve_bcd,
    synthetic_hyperspec,
    theorem1_bound,
)
from repro.core.convergence import corollary1_rounds, participation_rates
from repro.core.tiers import TierPlan, default_plan, synchronize
from repro.sim import (
    DeadlineLatency,
    deadline_for_rate,
    estimate_participation,
    make_trace,
    participation_masks,
    participation_problem,
)

CUTS = (3, 8)


def _params(key, N, U, d=4):
    ks = jax.random.split(key, 3)
    return {
        "frontend": {"embed": jax.random.normal(ks[0], (N, 8, d))},
        "units": {"w": jax.random.normal(ks[1], (N, U, d, d))},
        "head": {"norm": jax.random.normal(ks[2], (N, d))},
    }


def paper_problem(num_clients=20, num_edges=5, seed=0):
    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(
        num_clients=num_clients, num_edges=num_edges, seed=seed
    )
    hp = synthetic_hyperspec(VGG.n_units, num_clients, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], CUTS)
    return HsflProblem(prof, system, hp, eps=6.0 * floor)


# --------------------------------------------------------------------------- #
# mask-weighted synchronize
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", range(4))
def test_all_ones_mask_is_bit_exact(seed):
    """synchronize(mask=ones) == synchronize(mask=None), to the bit, at
    every step of the schedule (local and fed rounds)."""
    N, U = 8, 6
    params = _params(jax.random.PRNGKey(seed), N, U)
    plan = default_plan(U, N, cuts=(2, 4), intervals=(3, 2, 1), entities=(N, 4, 1))
    ones = jnp.ones(N, jnp.float32)
    for step in range(4):
        a = synchronize(params, plan, jnp.int32(step))
        b = synchronize(params, plan, jnp.int32(step), mask=ones)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.slow
def test_masked_weights_sum_to_one_per_group():
    """The aggregate is the participant mean: weights w_i/Σw sum to 1 per
    group, so aggregating all-equal replicas is the identity and a mixed
    group reproduces the exact participant average."""
    N, U = 6, 3
    params = _params(jax.random.PRNGKey(1), N, U)
    # tier 1 global at I=1, tier 2 entity groups of 2 at every round
    plan = default_plan(U, N, cuts=(1, 2), intervals=(1, 5, 1), entities=(N, 3, 1))
    mask = jnp.asarray(np.array([1, 0, 1, 1, 0, 0], np.float32))
    out = synchronize(params, plan, jnp.int32(0), mask=mask)
    w_in = np.asarray(params["units"]["w"], np.float64)
    w = np.asarray(out["units"]["w"])
    # tier 1 (unit 0): global fed mean over participants {0, 2, 3}
    expect = w_in[[0, 2, 3], 0].mean(0)
    for i in range(N):
        np.testing.assert_allclose(w[i, 0], expect, rtol=1e-6)
    # tier 2 (unit 1): entity {0,1} -> participant {0} alone (weight 1)
    np.testing.assert_allclose(w[0, 1], w_in[0, 1], rtol=1e-6)
    np.testing.assert_allclose(w[1, 1], w_in[0, 1], rtol=1e-6)
    # entity {2,3} -> mean of both
    np.testing.assert_allclose(w[2, 1], w_in[[2, 3], 1].mean(0), rtol=1e-6)


def test_masked_mean_permutation_invariant_within_entity():
    """Swapping clients within an entity (params and mask together) only
    permutes the output rows — the aggregate value doesn't change."""
    N, U = 8, 4
    params = _params(jax.random.PRNGKey(2), N, U)
    plan = default_plan(U, N, cuts=(1, 2), intervals=(1, 1, 1), entities=(N, 4, 1))
    mask = np.array([1, 0, 1, 1, 0, 1, 1, 0], np.float32)
    # swap clients 2 and 3 (both live in entity 1 = clients {2, 3})
    perm = np.array([0, 1, 3, 2, 4, 5, 6, 7])
    params_p = jax.tree.map(lambda x: x[perm], params)
    out = synchronize(params, plan, jnp.int32(0), mask=jnp.asarray(mask))
    out_p = synchronize(
        params_p, plan, jnp.int32(0), mask=jnp.asarray(mask[perm])
    )
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(out_p)):
        np.testing.assert_allclose(
            np.asarray(x)[perm], np.asarray(y), rtol=1e-6, atol=1e-7
        )


def test_zero_participant_group_keeps_last_synced_params():
    """A group with no participants is untouched by its level — the
    members keep the entity's last synced params (PR-4 convention)."""
    N, U = 6, 3
    params = _params(jax.random.PRNGKey(3), N, U)
    # tier 2's fed level is not due at step 0 (I=5): only the entity-level
    # Eq. 3 sync runs, so a dead entity is observable as unchanged params
    plan = default_plan(U, N, cuts=(1, 2), intervals=(1, 5, 1), entities=(N, 3, 1))
    mask = jnp.asarray(np.array([0, 0, 1, 1, 1, 0], np.float32))
    out = synchronize(params, plan, jnp.int32(0), mask=mask)
    w_in = np.asarray(params["units"]["w"])
    w = np.asarray(out["units"]["w"])
    # tier-2 entity {0,1} has zero participants: unit 1 rows unchanged
    np.testing.assert_array_equal(w[0, 1], w_in[0, 1])
    np.testing.assert_array_equal(w[1, 1], w_in[1, 1])
    # an all-zero mask leaves the whole tree unchanged, bit-for-bit
    out0 = synchronize(params, plan, jnp.int32(0), mask=jnp.zeros(N, jnp.float32))
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(out0)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------- #
# masks, rates, deadlines
# --------------------------------------------------------------------------- #


def small_trace(name="straggler-tail", num_clients=8, num_edges=2, rounds=12,
                seed=0, **kw):
    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(
        num_clients=num_clients, num_edges=num_edges, seed=seed
    )
    return make_trace(name, prof, system, rounds=rounds, seed=seed, **kw)


def test_participation_masks_semantics():
    trace = small_trace()
    dl = deadline_for_rate(trace, CUTS, 0.75)
    res = participation_masks(trace, CUTS, dl)
    assert res.masks.shape == (trace.rounds, trace.system.num_clients)
    # every round with available clients keeps >= 1 participant (d_eff rule)
    assert res.masks.any(axis=1).all()
    # q_tier[0] is the plain client rate; rates are per-round fractions
    np.testing.assert_allclose(res.q_tier[0], res.masks.mean())
    np.testing.assert_allclose(res.rates, res.masks.mean(axis=1))
    # round time is exactly the d_eff-capped straggler max, per round
    from repro.sim.participation import per_client_finish_times

    for r in range(trace.rounds):
        t = per_client_finish_times(trace, r, CUTS)
        avail = trace.round_state(r).available
        d_eff = max(dl, float(t[avail].min()))
        assert res.round_time[r] == min(d_eff, float(t[avail].max())), r
        np.testing.assert_array_equal(res.masks[r], avail & (t <= d_eff))
    # entity rate of the single-entity cloud tier is 1 whenever anyone runs
    assert res.q_tier[-1] == 1.0
    # tighter deadline -> (weakly) fewer participants
    res_tight = participation_masks(trace, CUTS, dl * 0.5)
    assert res_tight.masks.sum() <= res.masks.sum()


def test_deadline_for_rate_extremes():
    trace = small_trace()
    d_max = deadline_for_rate(trace, CUTS, 1.0)
    res = participation_masks(trace, CUTS, d_max)
    assert res.masks.all()  # everyone makes the global-max barrier
    assert res.q_tier.tolist() == [1.0, 1.0, 1.0]
    spec = estimate_participation(trace, CUTS, target_rate=1.0)
    assert spec.q == (1.0, 1.0, 1.0) and spec.deadline == d_max
    with pytest.raises(ValueError):
        estimate_participation(trace, CUTS)  # neither policy
    with pytest.raises(ValueError):
        estimate_participation(trace, CUTS, deadline=1.0, target_rate=0.5)
    with pytest.raises(ValueError):
        deadline_for_rate(trace, CUTS, 0.0)


def test_masks_depend_on_cut():
    """Finish times depend on the cut vector, so the same deadline admits
    different participant sets under different splits."""
    trace = small_trace()
    dl = deadline_for_rate(trace, CUTS, 0.6)
    a = participation_masks(trace, CUTS, dl)
    b = participation_masks(trace, (1, 2), dl)
    assert a.masks.shape == b.masks.shape
    assert not np.array_equal(a.masks, b.masks)


# --------------------------------------------------------------------------- #
# DeadlineLatency: scalar == batch, solver backend equivalence
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("scenario", ["straggler-tail", "flaky-wan", "diurnal-churn"])
def test_deadline_latency_batch_matches_scalar(scenario):
    trace = small_trace(scenario, rounds=6)
    problem = dataclasses.replace(
        paper_problem(num_clients=8, num_edges=2),
        profile=trace.profile, system=trace.system,
    )
    dl = deadline_for_rate(trace, CUTS, 0.7)
    lm = DeadlineLatency(trace, dl)
    lat = problem.cut_lattice()
    split_b, agg_b = lm.split_T_batch(lat), lm.agg_T_batch(lat)
    for k, cuts in enumerate(problem.iter_cut_vectors()):
        assert split_b[k] == lm.split_T(cuts), (scenario, cuts)
        for m in range(problem.M - 1):
            assert agg_b[k, m] == lm.agg_T(cuts, m), (scenario, cuts, m)


def test_deadline_latency_jax_backend_bit_equal():
    pytest.importorskip("jax")
    trace = small_trace(rounds=5)
    dl = deadline_for_rate(trace, CUTS, 0.7)
    lat = np.asarray([CUTS, (1, 2), (2, 6)], dtype=np.int64)
    a = DeadlineLatency(trace, dl, backend="numpy")
    b = DeadlineLatency(trace, dl, backend="jax")
    np.testing.assert_array_equal(a.split_T_batch(lat), b.split_T_batch(lat))
    np.testing.assert_array_equal(a.agg_T_batch(lat), b.agg_T_batch(lat))


def test_participation_problem_solver_backends_identical():
    base = paper_problem()
    trace = make_trace(
        "straggler-tail", base.profile, base.system, rounds=16, seed=0
    )
    pp = participation_problem(base, trace, target_rate=0.75)
    assert pp.participation is not None and pp.participation.deadline > 0
    rs = solve_bcd(pp, backend="scalar")
    rn = solve_bcd(pp, backend="numpy")
    assert rs.cuts == rn.cuts
    assert tuple(rs.intervals) == tuple(rn.intervals)
    assert rs.theta == rn.theta and rs.rounds == rn.rounds


def test_participation_problem_full_rate_prices_expectation():
    """target_rate=1.0: nobody is dropped (q == 1, bound untouched) and
    T_S is the trace *expectation* of the uncapped round."""
    base = paper_problem(num_clients=8, num_edges=2)
    trace = small_trace(rounds=10)
    # estimate the barrier at CUTS so the pooled max covers CUTS's rounds
    pp = participation_problem(base, trace, target_rate=1.0, cuts=CUTS)
    assert pp.participation.q == (1.0, 1.0, 1.0)
    c_pp, k_pp = pp.constants()
    c0, k0 = base.constants()
    assert (c_pp, k_pp) == (c0, k0)
    np.testing.assert_array_equal(pp.tier_d(CUTS), base.tier_d(CUTS))
    from repro.sim import simulate_rounds

    res = simulate_rounds(trace, CUTS)
    assert pp.split_T(CUTS) == float(np.mean(res.split))


def test_participation_problem_compression_threading():
    from repro.compress import CompressionSpec

    base = paper_problem(num_clients=8, num_edges=2)
    int8 = CompressionSpec.uniform(3, 0.25, omega=0.004)
    trace = small_trace(rounds=6)
    pp = participation_problem(
        base.with_compression(int8), trace, target_rate=0.8
    )
    assert pp.latency_model.trace.compression == int8
    topk = CompressionSpec.uniform(3, 0.5, omega=0.75)
    with pytest.raises(ValueError):
        participation_problem(
            base.with_compression(int8), trace.with_compression(topk),
            target_rate=0.8,
        )


def test_with_participation_guards():
    base = paper_problem(num_clients=8, num_edges=2)
    spec = ParticipationSpec(q=(0.5, 1.0, 1.0), deadline=1.0)
    p = base.with_participation(spec)
    assert p.participation == spec
    with pytest.raises(ValueError):
        base.with_participation(ParticipationSpec(q=(0.5, 1.0)))  # wrong M
    with pytest.raises(ValueError):
        base.with_participation(ParticipationSpec(q=(0.0, 1.0, 1.0)))
    trace = small_trace(rounds=4)
    pp = participation_problem(
        paper_problem(num_clients=8, num_edges=2), trace, target_rate=0.9
    )
    with pytest.raises(ValueError):  # latency model prices the old policy
        pp.with_participation(spec)


# --------------------------------------------------------------------------- #
# bound inflation
# --------------------------------------------------------------------------- #


def test_bound_q1_is_bit_identical_to_plain():
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=0)
    iv = (2, 3, 1)
    ones = ParticipationSpec(q=(1.0, 1.0, 1.0))
    assert theorem1_bound(hp, 50, iv, CUTS) == theorem1_bound(
        hp, 50, iv, CUTS, participation=ones
    )
    assert corollary1_rounds(hp, 1000.0, iv, CUTS) == corollary1_rounds(
        hp, 1000.0, iv, CUTS, participation=ones
    )


def test_bound_monotone_in_q():
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=0)
    iv = (2, 3, 1)
    prev = theorem1_bound(hp, 50, iv, CUTS)
    for q in (0.9, 0.6, 0.3):
        cur = theorem1_bound(hp, 50, iv, CUTS, participation=q)
        assert cur > prev, (q, cur, prev)
        prev = cur
    # fewer participants -> more rounds to the same eps (when reachable)
    eps = 3.0 * theorem1_bound(hp, 10**9, iv, CUTS)
    r_full = corollary1_rounds(hp, eps, iv, CUTS)
    r_half = corollary1_rounds(hp, eps, iv, CUTS, participation=0.5)
    assert r_half is None or r_half > r_full


def test_participation_rates_validation():
    assert participation_rates(None, 3).tolist() == [1.0, 1.0, 1.0]
    assert participation_rates(0.5, 3).tolist() == [0.5, 0.5, 0.5]
    assert participation_rates((0.5, 0.75, 1.0), 3).tolist() == [0.5, 0.75, 1.0]
    with pytest.raises(ValueError):
        participation_rates((0.5, 0.75), 3)
    with pytest.raises(ValueError):
        participation_rates(1.5, 3)
    with pytest.raises(ValueError):
        participation_rates(0.0, 3)


def test_scalar_and_batched_denominators_agree_under_participation():
    base = paper_problem()
    p = base.with_participation(
        ParticipationSpec(q=(0.6, 0.8, 1.0), deadline=0.5)
    )
    ev = p.evaluator("numpy")
    for k, cuts in enumerate(p.iter_cut_vectors()):
        assert ev.split[k] == p.split_T(cuts)  # nominal split capped at 0.5
        assert ev.split[k] <= 0.5
        for iv in ((1, 1, 1), (2, 3, 1), (4, 2, 1)):
            assert ev.denominator(iv)[k] == p.denominator(iv, cuts)
            assert ev.theta(iv)[k] == p.theta(iv, cuts)


# --------------------------------------------------------------------------- #
# API: spec round-trip, build, train
# --------------------------------------------------------------------------- #


def participation_api_spec(rate=0.8, rounds=12, seed=0):
    from repro.api import ParticipationCfg, ScenarioCfg, paper_spec

    return paper_spec(seed=seed).replace(
        scenario=ScenarioCfg(name="straggler-tail", rounds=rounds, seed=seed),
        participation=ParticipationCfg(target_rate=rate),
        name="participation-test",
    )


def test_spec_round_trip_and_build():
    import json

    from repro.api import ExperimentSpec, ParticipationCfg, build

    spec = participation_api_spec()
    d = json.loads(json.dumps(spec.to_dict()))
    assert ExperimentSpec.from_dict(d) == spec
    built = build(spec)
    assert built.participation is not None
    assert built.problem.participation == built.participation
    assert 0.0 < built.participation.q[0] <= 1.0
    # deadline policy round-trips too
    spec2 = spec.replace(
        participation=ParticipationCfg(deadline=0.25, cuts=(2, 5))
    )
    d2 = json.loads(json.dumps(spec2.to_dict()))
    assert ExperimentSpec.from_dict(d2) == spec2
    built2 = build(spec2)
    assert built2.participation.deadline == 0.25


def test_participation_cfg_validation():
    from repro.api import ParticipationCfg

    with pytest.raises(ValueError):
        ParticipationCfg()  # neither policy
    with pytest.raises(ValueError):
        ParticipationCfg(deadline=0.5, target_rate=0.5)  # both
    with pytest.raises(ValueError):
        ParticipationCfg(deadline=-1.0)
    with pytest.raises(ValueError):
        ParticipationCfg(target_rate=1.5)


def test_participation_without_scenario_rejected():
    from repro.api import ParticipationCfg, build, paper_spec

    spec = paper_spec().replace(
        participation=ParticipationCfg(target_rate=0.5)
    )
    with pytest.raises(ValueError, match="scenario"):
        build(spec)


def test_run_solve_bit_identical_without_participation():
    """The participation=None API path is unchanged: identical result to a
    spec that never heard of the feature (acceptance pin)."""
    from repro.api import paper_spec, run

    res = run(paper_spec(seed=0))
    assert res.provenance.get("participation") is None
    # the seeded paper optimum (also pinned by benchmarks): stable schedule
    assert res.theta > 0 and res.rounds_to_eps is not None


# --------------------------------------------------------------------------- #
# zero-participant-round convention across every path
# --------------------------------------------------------------------------- #


def test_zero_participant_round_convention_all_paths():
    """One documented behavior everywhere: a zero-available round prices
    split = 0 and skips client-hosted syncs in the event oracle, the fleet
    fast path, the lattice path, AND the deadline-pricing path; the mask
    path's all-zero round is a parameter no-op."""
    from repro.sim import simulate, simulate_rounds
    from repro.sim.fleet import simulate_lattice_rounds
    from repro.sim.scenarios import SystemTrace

    prof = build_profile(VGG, batch=4)
    system = SystemSpec.paper_three_tier(num_clients=6, num_edges=2, seed=0)
    base = make_trace("homogeneous-paper", prof, system, rounds=4, seed=0)
    empty = dataclasses.replace(
        base.round_state(0),
        available=np.zeros(system.num_clients, dtype=bool),
    )
    trace = SystemTrace(
        "with-dead-round", prof, system, base.rounds, 0,
        lambda r: empty if r == 1 else base.round_state(r),
    )
    cuts = (3, 8)
    ev = simulate(trace, cuts)
    fl = simulate_rounds(trace, cuts, backend="numpy")
    np.testing.assert_array_equal(ev.split, fl.split)
    assert ev.split[1] == 0.0 and ev.agg[0, 1] == 0.0

    lat = np.asarray([cuts], dtype=np.int64)
    dl = float(np.max(fl.split)) * 2.0  # generous barrier
    split_b, agg_b = simulate_lattice_rounds(
        trace, lat, backend="numpy", deadline=dl
    )
    assert split_b[0, 1] == 0.0 and agg_b[0, 0, 1] == 0.0

    lm = DeadlineLatency(trace, dl)
    split_s, agg_s = lm.per_round(cuts)
    np.testing.assert_array_equal(split_s, split_b[0])
    np.testing.assert_array_equal(agg_s, agg_b[0])
    assert split_s[1] == 0.0

    pr = participation_masks(trace, cuts, dl)
    assert not pr.masks[1].any()          # nobody available, nobody masked in
    assert pr.round_time[1] == 0.0        # the dead round costs nothing
    assert pr.masks[0].all()              # generous barrier: everyone else in

    # mask path: the all-zero round is a no-op on params (bit-for-bit)
    params = _params(jax.random.PRNGKey(0), system.num_clients, 6)
    plan = default_plan(
        6, system.num_clients, cuts=(2, 4), intervals=(1, 1, 1),
        entities=system.entities,
    )
    out = synchronize(
        params, plan, jnp.int32(0),
        mask=jnp.asarray(pr.masks[1], jnp.float32),
    )
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------- #
# masked Engine A on a tiny model (fast-suite coverage; the full A/B
# differential matrix lives in tests/test_engines_equal.py, nightly)
# --------------------------------------------------------------------------- #


def _tiny_vgg_setup(N=4):
    from repro.models.vgg import VggModel, VggSpec
    from repro.optim import sgd

    spec = VggSpec(
        name="vgg-tiny", conv_channels=(4, 8), pool_after=(0,),
        fc_dims=(16, 10), image_size=8, in_channels=3, num_classes=10,
    )
    model = VggModel(spec)
    plan = default_plan(
        spec.n_units, N, cuts=(1, 2), intervals=(2, 1, 1), entities=(N, 2, 1)
    )
    return spec, model, plan, sgd(0.05)


def _tiny_batch(spec, N, b, seed):
    rng = np.random.default_rng(seed)
    return {
        "images": jnp.asarray(
            rng.normal(size=(N, b, spec.image_size, spec.image_size, 3)),
            jnp.float32,
        ),
        "labels": jnp.asarray(
            rng.integers(0, spec.num_classes, (N, b)), jnp.int32
        ),
    }


@pytest.mark.slow
def test_engine_a_masked_step_semantics():
    from repro.core import build_train_step_a, init_state_a

    N = 4
    spec, model, plan, opt = _tiny_vgg_setup(N)
    key = jax.random.PRNGKey(0)
    s_plain = init_state_a(model, plan, opt, key)
    s_mask = init_state_a(model, plan, opt, key)
    step_plain = jax.jit(build_train_step_a(model, plan, opt))
    step_mask = jax.jit(build_train_step_a(model, plan, opt, with_mask=True))

    # all-ones mask: bit-identical to the unmasked step, every round
    for t in range(3):
        batch = _tiny_batch(spec, N, 2, t)
        s_plain, l0 = step_plain(s_plain, batch)
        s_mask, l1 = step_mask(s_mask, batch, jnp.ones(N, jnp.float32))
        assert float(l0) == float(l1)
    for a, b in zip(jax.tree.leaves(s_plain.params), jax.tree.leaves(s_mask.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # an all-zero mask is a whole-round no-op (loss 0, params frozen)
    batch = _tiny_batch(spec, N, 2, 99)
    s_after, loss = step_mask(s_mask, batch, jnp.zeros(N, jnp.float32))
    assert float(loss) == 0.0
    for a, b in zip(jax.tree.leaves(s_mask.params), jax.tree.leaves(s_after.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_api_train_with_participation_masks():
    """run(mode="train") under a participation policy drives the masked
    engine with trace-sampled masks and reports the realized rate."""
    from repro.api import (
        HyperCfg, ModelCfg, ParticipationCfg, RunCfg, ScenarioCfg,
        SolverCfg, SystemCfg, ExperimentSpec, run,
    )

    spec = ExperimentSpec(
        name="train-masked",
        model=ModelCfg(
            arch="smollm-135m", variant="reduced", num_layers=4, batch=2, seq=8
        ),
        system=SystemCfg(
            preset="paper-three-tier", num_clients=8, num_edges=4, seed=0
        ),
        hyper=HyperCfg(seed=0),
        scenario=ScenarioCfg(name="straggler-tail", rounds=16, seed=0),
        participation=ParticipationCfg(target_rate=0.5),
        solver=SolverCfg(kind="fixed", cuts=(1, 3), intervals=(2, 2, 1)),
        run=RunCfg(mode="train", seed=0, rounds=4, lr=0.05, dataset_size=32),
    )
    res = run(spec)
    assert res.train["deadline"] > 0
    assert 0.0 < res.train["mean_participation"] <= 1.0
    assert np.isfinite(res.train["final_loss"])
