from .ops import swa_attention
from .ref import swa_attention_ref
