"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
import dataclasses
from ..models.spec import ModelSpec

SPEC = ModelSpec(
    name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=13824, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B",
)

REDUCED = dataclasses.replace(
    SPEC, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=32,
)
