"""Privacy & energy as first-class costs (DESIGN.md §15).

Three claims, all asserted:

1. **Exact collapse** — a spec with noise_multiplier=0, no ε budget, and
   all-zero energy prices solves to the *bit-identical* schedule, Θ', and
   R-to-ε as the unconstrained paper problem: the DP σ² term, the
   denominator floor, and the energy mask are all structurally absent
   when their knobs are off.
2. **Solver retreat** — tightening the (ε, δ) budget monotonically caps
   the accountant's round allowance R_max, and the BCD optimum retreats
   to schedules whose R-to-ε fits under it (shorter intervals, weakly
   worse Θ'); a binding per-round energy budget moves the optimum off
   the unconstrained point while keeping E(I, μ) ≤ budget.
3. **Bound envelope** — a REAL Engine-A training run with the Gaussian
   mechanism on the fed wire (per-client clip + noise, under partial
   participation masks) keeps its measured average gradient norm below
   the σ²-inflated Theorem-1 bound evaluated with constants estimated
   from the same run.
"""
from __future__ import annotations

import numpy as np

from .common import emit, record


def _solver_rows(quick: bool, seed: int) -> list:
    from repro.api import (
        EnergyCfg,
        PrivacyCfg,
        build,
        paper_spec,
        privacy_energy_spec,
        run,
    )
    from repro.privacy import Accountant

    rows = []

    # -- claim 1: bit-exact collapse of the unconstrained spec ----------- #
    base = run(paper_spec(seed=seed))
    free = paper_spec(seed=seed).replace(
        privacy=PrivacyCfg(noise_multiplier=0.0),
        energy=EnergyCfg(
            compute_j_per_flop=0.0, act_j_per_byte=0.0, model_j_per_byte=0.0
        ),
    )
    rfree = run(free)
    collapse = (
        rfree.cuts == base.cuts
        and rfree.intervals == base.intervals
        and rfree.theta == base.theta
        and rfree.rounds_to_eps == base.rounds_to_eps
    )
    rows.append(
        ("noiseless/free == unconstrained (bit-exact)",
         f"{base.cuts}/{base.intervals}", f"{rfree.cuts}/{rfree.intervals}",
         collapse)
    )
    assert collapse, (base, rfree)

    # -- claim 2a: ε-budget sweep — solver retreat ----------------------- #
    # reporting-only run fixes the ε scale of this problem; budgets are
    # then placed inside the feasible round band [R(I=1), R*].
    spec0 = privacy_energy_spec(seed=seed)
    b0 = build(spec0)
    r0 = record(run(spec0, built=b0))
    R_star = r0.rounds_to_eps
    R_min = b0.problem.rounds((1,) * b0.system.M, r0.cuts)
    acc = Accountant(
        noise_multiplier=b0.privacy.noise_multiplier,
        sampling_rate=1.0,
        delta=b0.privacy.delta,
    )
    fracs = (1.0, 0.5, 0.05) if quick else (1.0, 0.7, 0.4, 0.1, 0.02)
    prev_theta = r0.theta
    moved = False
    for t in fracs:
        eps_b = acc.epsilon(int(np.ceil(R_min + t * (R_star - R_min))))
        spec = privacy_energy_spec(seed=seed, epsilon_budget=eps_b)
        res = record(run(spec))
        ok = (
            res.rounds_to_eps <= res.privacy["max_rounds"] * (1 + 1e-9)
            and res.theta >= prev_theta - 1e-9 * abs(prev_theta)
        )
        moved = moved or res.intervals != r0.intervals or res.cuts != r0.cuts
        rows.append(
            (f"eps_budget={eps_b:.1f}",
             f"{res.cuts}/{res.intervals}",
             f"R={res.rounds_to_eps:.0f}<=R_max={res.privacy['max_rounds']:.0f}",
             ok)
        )
        assert ok, res
        prev_theta = res.theta
    rows.append(("tight eps moved the schedule", "-", "-", moved))
    assert moved, "no ε budget in the sweep moved the optimum"

    # -- claim 2b: energy budget — retreat off the unconstrained point --- #
    # The floor is the cheapest FEASIBLE round (mem ok, D > d_min): large
    # intervals amortize aggregation energy but eventually kill D > 0, so
    # scan a geometric I grid × the whole lattice.  Any budget strictly
    # between that floor and E(opt) binds yet stays satisfiable.
    import itertools

    E_opt = b0.problem.round_energy(r0.intervals, r0.cuts)
    ev = b0.problem.evaluator("numpy")
    E_floor = float("inf")
    for I in itertools.product((1, 2, 4, 8, 16, 32, 64),
                               repeat=b0.system.M - 1):
        iv = I + (1,)
        ok = ev.mem_ok & (ev.denominator(iv) > ev.d_min)
        if ok.any():
            E_floor = min(E_floor, float(ev.round_energy(iv)[ok].min()))
    budget = 0.5 * (E_floor + E_opt)
    spec_e = privacy_energy_spec(seed=seed, budget_j_per_round=budget)
    res_e = record(run(spec_e))
    E_new = res_e.energy["round_energy_j"]
    ok = (
        (res_e.cuts, res_e.intervals) != (r0.cuts, r0.intervals)
        and E_new <= budget
        and res_e.theta >= r0.theta - 1e-9 * abs(r0.theta)
    )
    rows.append(
        (f"energy_budget={budget:.1f}J",
         f"{r0.cuts}/{r0.intervals} E={E_opt:.1f}J",
         f"{res_e.cuts}/{res_e.intervals} E={E_new:.1f}J",
         ok)
    )
    assert ok, (res_e, budget, E_opt)
    return rows


def _envelope_rows(quick: bool, seed: int) -> list:
    """Claim 3: σ²-inflated Theorem 1 envelopes a DP-noised masked run."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.vgg16_cifar10 import SPEC as VGG
    from repro.core import build_train_step_a, init_state_a
    from repro.core.convergence import theorem1_bound
    from repro.core.estimator import HyperEstimator
    from repro.core.tiers import default_plan
    from repro.data import image_loader, make_cifar10_like, partition_iid
    from repro.models.vgg import VggModel
    from repro.optim import sgd
    from repro.privacy import DPMechanism, PrivacySpec

    spec = dataclasses.replace(
        VGG, conv_channels=(8, 16, 16), pool_after=(0, 1), fc_dims=(32, 10),
        name="vgg-tiny",
    )
    N, gamma, q = 4, 0.01, 0.75
    rounds = 12 if quick else 25
    ds = make_cifar10_like(256, noise=0.4, seed=seed + 3)
    loader = image_loader(
        ds, partition_iid(len(ds), N, seed + 3), batch=8, seed=seed + 3
    )
    model = VggModel(spec)
    eval_batch = {"images": jnp.asarray(ds.images[:192]),
                  "labels": jnp.asarray(ds.labels[:192])}
    gbar_fn = jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b))

    # the mechanism dimension = trainable parameter count of THIS model
    plan = default_plan(spec.n_units, N, cuts=(2, 3), intervals=(2, 1, 1),
                        entities=(N, 2, 1))
    opt = sgd(gamma)
    state0 = init_state_a(model, plan, opt, jax.random.PRNGKey(seed + 3))
    dim = int(sum(
        x[0].size for x in jax.tree.leaves(state0.params)
    ))

    rng = np.random.default_rng(seed + 11)
    masks = (rng.random((rounds, N)) < q).astype(np.float32)
    masks[masks.sum(axis=1) == 0, 0] = 1.0  # every round keeps a participant

    rows = []
    for z, clip in ((0.0, 1.0), (0.5, 0.05)):
        mech = (
            None if z == 0.0
            else DPMechanism(clip=clip, noise_multiplier=z, seed=seed)
        )
        step = jax.jit(build_train_step_a(
            model, plan, opt, with_mask=True, privacy=mech
        ))
        grad_fn = jax.jit(
            lambda p, b: jax.vmap(jax.value_and_grad(model.loss_fn))(p, b)
        )
        state = init_state_a(model, plan, opt, jax.random.PRNGKey(seed + 3))
        est = HyperEstimator(plan.n_units, N, gamma)
        sq_norms = []
        for r in range(rounds):
            batch = {k: jnp.asarray(v) for k, v in loader.next_round().items()}
            losses, grads = grad_fn(state.params, batch)
            est.observe(state.params, grads, float(jnp.mean(losses)))
            wbar = jax.tree.map(lambda x: jnp.mean(x, axis=0), state.params)
            g = gbar_fn(wbar, eval_batch)
            sq_norms.append(float(
                sum(jnp.sum(x * x) for x in jax.tree.leaves(g))
            ))
            state, _ = step(state, batch, jnp.asarray(masks[r]))
        hp = est.hyperspec()
        dp_sigma2 = PrivacySpec(
            noise_multiplier=z, clip=clip, dim=dim
        ).dp_sigma2
        measured = float(np.mean(sq_norms))
        bound = theorem1_bound(
            hp, rounds, plan.intervals, plan.cuts,
            participation=q, dp_sigma2=dp_sigma2,
        )
        rows.append(
            (f"z={z} C={clip} (dp_sigma2={dp_sigma2:.3g})",
             measured, bound, measured <= bound)
        )
    emit(rows, ("mechanism", "measured_avg_grad_sq", "noised_thm1_bound",
                "holds"))
    assert all(r[3] for r in rows), rows
    return rows


def main(quick: bool = False, seed: int = 0) -> list:
    rows = _solver_rows(quick, seed)
    emit(rows, ("case", "reference", "constrained", "ok"))
    rows += _envelope_rows(quick, seed)
    return rows


if __name__ == "__main__":
    main()
