"""Build-time capability matrix (api.build.check_capabilities).

Every unsupported spec combination must fail at build time with the ONE
message shape ``unsupported spec combination: {combo} requires {need} —
{why}`` — never a step-build NotImplementedError deep in core.engine.
"""
import dataclasses

import pytest

from repro.api import (
    ClassesCfg,
    FaultsCfg,
    PrivacyCfg,
    build,
    quickstart_spec,
)
from repro.api.build import check_capabilities
from repro.api.spec import RunCfg, ShardingCfg

MSG = "unsupported spec combination"


def qs(**run_over):
    spec = quickstart_spec(rounds=2)
    if run_over:
        spec = spec.replace(run=dataclasses.replace(spec.run, **run_over))
    return spec


def test_supported_combinations_pass():
    check_capabilities(qs())
    check_capabilities(qs(engine="b"))
    check_capabilities(qs(sharding=ShardingCfg(data=2)))
    check_capabilities(qs(staleness=1))
    check_capabilities(qs(sharding=ShardingCfg(data=2), staleness=(1, 0, 0)))
    # a NOISELESS privacy section composes to nothing: sharding-safe
    check_capabilities(
        qs(sharding=ShardingCfg(data=2)).replace(
            privacy=PrivacyCfg(noise_multiplier=0.0)
        )
    )


@pytest.mark.parametrize(
    "section",
    [
        dict(classes=ClassesCfg(num_classes=2)),
        dict(privacy=PrivacyCfg(noise_multiplier=1.0)),
        dict(faults=FaultsCfg(crash_rate=0.1)),
    ],
    ids=["classes", "privacy", "faults"],
)
def test_engine_b_feature_matrix(section):
    spec = qs(engine="b").replace(**section)
    with pytest.raises(ValueError, match=MSG) as e:
        build(spec)
    assert 'engine="a"' in str(e.value)


def test_engine_b_rejects_sharding_and_staleness():
    with pytest.raises(ValueError, match=f"{MSG}: sharding"):
        build(qs(engine="b", sharding=ShardingCfg(data=2)))
    with pytest.raises(ValueError, match=f"{MSG}: staleness"):
        build(qs(engine="b", staleness=1))


@pytest.mark.parametrize(
    "feature_over",
    [dict(sharding=ShardingCfg(data=2)), dict(staleness=1)],
    ids=["sharding", "staleness"],
)
def test_sharded_async_feature_matrix(feature_over):
    feature = next(iter(feature_over))
    with pytest.raises(ValueError, match=f"{MSG}: {feature} × privacy"):
        build(qs(**feature_over).replace(
            privacy=PrivacyCfg(noise_multiplier=1.0)
        ))
    with pytest.raises(ValueError, match=f"{MSG}: {feature} × classes"):
        build(qs(**feature_over).replace(classes=ClassesCfg(num_classes=2)))
    with pytest.raises(ValueError, match=f"{MSG}: {feature} × faults"):
        build(qs(**feature_over).replace(faults=FaultsCfg(crash_rate=0.1)))
    with pytest.raises(ValueError, match=f'{MSG}: {feature} × mode="control"'):
        build(qs(mode="control", **feature_over))


def test_message_shape_is_uniform():
    with pytest.raises(ValueError) as e:
        build(qs(engine="b").replace(faults=FaultsCfg(crash_rate=0.1)))
    msg = str(e.value)
    assert msg.startswith("unsupported spec combination: ")
    assert " requires " in msg and " — " in msg
