"""Participation sweep: the straggler deadline priced end-to-end (DESIGN.md §12).

Three asserted claims, not just tables:

1. **Crossover sweep** — on the paper preset under the straggler-tail
   fleet, tightening the deadline (target rate 1.0 → 0.5) weakly lowers
   the BCD optimum's expected round time (rounds stop waiting for the
   tail) while the 1/q-inflated Theorem-1 bound weakly raises
   rounds-to-ε — the round-time vs rounds-to-ε trade the solvers
   navigate.  At the P50 deadline the expected round time sits strictly
   below the full-participation round time and the inflated bound still
   certifies convergence (finite R).
2. **Full-participation identity** — target rate 1.0 estimates q ≡ 1
   exactly, and the q≡1-inflated bound equals the plain bound bit-for-bit
   (partial participation is a strict generalization).
3. **Masked training** — a real (tiny-VGG) Engine-A run with
   deadline-driven masks sampled from the fleet trace: participation lands
   strictly inside (0, 1), the loss still trains, and the run is
   reproducible (same spec → same losses).
"""
from __future__ import annotations

import numpy as np

from .common import emit, record


# --------------------------------------------------------------------------- #
# 1. deadline sweep through the BCD solver
# --------------------------------------------------------------------------- #


def crossover_sweep(quick: bool, seed: int) -> list:
    from repro.api import ParticipationCfg, ScenarioCfg, build, paper_spec, run

    rates = (1.0, 0.75, 0.5) if quick else (1.0, 0.9, 0.75, 0.6, 0.5)
    rounds = 16 if quick else 48
    base = paper_spec(seed=seed).replace(
        scenario=ScenarioCfg(name="straggler-tail", rounds=rounds, seed=seed)
    )
    results = []
    for rate in rates:
        spec = base.replace(
            name=f"participation-q{rate}",
            participation=ParticipationCfg(target_rate=rate),
        )
        built = build(spec)
        res = record(run(spec, built=built))
        results.append((rate, built.participation, res))
    rows = [
        (rate, f"{p.deadline:.4g}", f"{p.q[0]:.3f}", str(res.cuts),
         str(tuple(res.intervals)), res.latency["split_T"],
         res.rounds_to_eps, res.total_latency)
        for rate, p, res in results
    ]
    emit(rows, ("target_rate", "deadline_s", "q1", "cuts", "intervals",
                "expected_round_T", "rounds_to_eps", "converged_T"))

    split = [res.latency["split_T"] for _, _, res in results]
    R = [res.rounds_to_eps for _, _, res in results]
    # the inflated bound must still certify convergence at every deadline
    assert all(r is not None and np.isfinite(r) for r in R), R
    # tighter deadline -> weakly cheaper expected rounds, weakly more of them
    assert all(a >= b - 1e-12 for a, b in zip(split, split[1:])), split
    assert all(a <= b * (1 + 1e-12) for a, b in zip(R, R[1:])), R
    # acceptance pin: at the P50 deadline, expected round time strictly
    # below the full-participation (rate 1.0) round time
    assert split[-1] < split[0], (split[-1], split[0])
    return rows


# --------------------------------------------------------------------------- #
# 2. full participation is the exact q ≡ 1 special case
# --------------------------------------------------------------------------- #


def full_participation_identity(quick: bool, seed: int) -> list:
    from repro.api import ParticipationCfg, ScenarioCfg, build, paper_spec
    from repro.core.convergence import theorem1_bound

    rounds = 16 if quick else 48
    spec = paper_spec(seed=seed).replace(
        scenario=ScenarioCfg(name="straggler-tail", rounds=rounds, seed=seed),
        participation=ParticipationCfg(target_rate=1.0),
    )
    built = build(spec)
    q = built.participation.q
    assert q == (1.0,) * built.system.M, q  # everyone makes the global-max barrier
    cuts, intervals = (3, 8), (2, 3, 1)
    plain = theorem1_bound(built.hyper, 100, intervals, cuts)
    inflated = theorem1_bound(
        built.hyper, 100, intervals, cuts, participation=built.participation
    )
    rows = [("q==1 bound == plain bound", plain, inflated, plain == inflated)]
    emit(rows, ("identity", "plain", "q1_inflated", "bit_equal"))
    assert plain == inflated, (plain, inflated)
    return rows


# --------------------------------------------------------------------------- #
# 3. real masked training off the sampled fleet masks
# --------------------------------------------------------------------------- #


def masked_training(quick: bool, seed: int) -> list:
    from repro.api import (
        ModelCfg, ParticipationCfg, RunCfg, ScenarioCfg, SolverCfg,
        paper_spec, run,
    )

    rounds = 4 if quick else 16
    spec = paper_spec(seed=seed).replace(
        name="participation-train",
        model=ModelCfg(arch="vgg16-cifar10", batch=4),
        scenario=ScenarioCfg(name="straggler-tail", rounds=32, seed=seed),
        participation=ParticipationCfg(target_rate=0.5),
        solver=SolverCfg(kind="fixed", cuts=(2, 4), intervals=(2, 2, 1)),
        run=RunCfg(mode="train", seed=seed, rounds=rounds, lr=0.05,
                   dataset_size=128),
    )
    res = record(run(spec))
    res2 = run(spec)
    rate = res.train["mean_participation"]
    rows = [(res.train["engine"], rounds, f"{rate:.3f}",
             res.train["first_loss"], res.train["final_loss"],
             res.train["losses"] == res2.train["losses"])]
    emit(rows, ("engine", "rounds", "mean_participation", "first_loss",
                "final_loss", "reproducible"))
    assert 0.0 < rate < 1.0, rate  # the deadline actually drops stragglers
    assert np.isfinite(res.train["final_loss"]), res.train
    assert res.train["losses"] == res2.train["losses"]
    return rows


def main(quick: bool = False, seed: int = 0) -> list:
    out = []
    out += crossover_sweep(quick, seed)
    out += full_participation_identity(quick, seed)
    out += masked_training(quick, seed)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.quick, seed=args.seed)
