"""Architecture specifications for the splittable model zoo.

Every model is a *frontend* + an ordered list of *units* + a *head*.
HSFL cut layers index unit boundaries: cut vector ``c = (c_1, .., c_{M-1})``
with ``0 <= c_1 <= ... <= c_{M-1} <= n_units`` assigns units
``[c_{m-1}, c_m)`` to tier ``m`` (``c_0 = 0``, ``c_M = n_units``); the
frontend always lives with tier 1 and the head with tier M.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class MoeSpec:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SsmSpec:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ModelSpec:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | vgg
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    moe: Optional[MoeSpec] = None
    ssm: Optional[SsmSpec] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # hybrid (jamba): one attention layer per `attn_period` layers, MoE FFN
    # every `moe_period`-th layer (others dense MLP).
    attn_period: int = 0
    moe_period: int = 0
    # encoder-decoder (whisper): num_layers counts DECODER layers.
    encoder_layers: int = 0
    encoder_len: int = 1500
    # vlm (paligemma): number of image-prefix tokens (stub embeddings).
    prefix_len: int = 0
    # sliding window (0 = full attention). The long_500k shape forces a
    # window via `spec.with_window(...)` for quadratic-attention archs.
    window: int = 0
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # rematerialize unit activations in the backward pass (activation
    # checkpointing at unit granularity — the policy C5 prices).
    remat: bool = False
    # remat policy: "full" recomputes everything inside a unit;
    # "dots" (jax dots_with_no_batch_dims_saveable) saves matmul outputs,
    # skipping the re-forward matmuls AND their TP collectives at the cost
    # of more saved-activation memory (perf lever, EXPERIMENTS.md sect. Perf).
    remat_policy: str = "full"
    # source citation (public pool assignment)
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, 256)

    @property
    def n_units(self) -> int:
        """Number of HSFL-cuttable units."""
        if self.family == "hybrid":
            return self.num_layers // self.attn_period  # super-blocks
        if self.family == "audio":
            return self.encoder_layers + self.num_layers
        return self.num_layers

    @property
    def layers_per_unit(self) -> int:
        return self.attn_period if self.family == "hybrid" else 1

    def with_window(self, window: int) -> "ModelSpec":
        return dataclasses.replace(self, window=window)

    def with_dtypes(self, param: str, compute: str) -> "ModelSpec":
        return dataclasses.replace(self, param_dtype=param, compute_dtype=compute)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    # ---------------- analytic size/FLOP accounting ------------------- #
    def unit_param_count(self, unit: int) -> int:
        """Parameters in one unit (used by the HSFL latency/memory model)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        h, k = self.num_heads, self.num_kv_heads

        def attn_params() -> int:
            p = d * h * hd + 2 * d * k * hd + h * hd * d
            if self.qkv_bias:
                p += h * hd + 2 * k * hd
            if self.qk_norm:
                p += 2 * hd
            return p + d  # + norm

        def mlp_params(width: int) -> int:
            return 3 * d * width + d  # swiglu + norm

        def moe_params(ms: MoeSpec) -> int:
            return d * ms.num_experts + ms.num_experts * 3 * d * ff + d

        def mamba_params(ss: SsmSpec) -> int:
            di = ss.expand * d
            nh = di // ss.head_dim
            in_p = d * (2 * di + 2 * ss.state_dim + nh)
            return in_p + di * ss.conv_width + 3 * nh + di + di * d + d

        if self.family in ("dense", "vlm"):
            return attn_params() + mlp_params(ff)
        if self.family == "moe":
            return attn_params() + moe_params(self.moe)
        if self.family == "ssm":
            return mamba_params(self.ssm)
        if self.family == "hybrid":
            per = self.attn_period
            n_moe = per // self.moe_period
            n_mlp = per - n_moe
            return (
                attn_params()
                + (per - 1) * mamba_params(self.ssm)
                + n_moe * moe_params(self.moe)
                + n_mlp * mlp_params(ff)
            )
        if self.family == "audio":
            # encoder unit == decoder unit + cross-attention block
            enc = attn_params() + mlp_params(ff)
            dec = 2 * attn_params() + mlp_params(ff)
            return dec if unit >= self.encoder_layers else enc
        raise ValueError(self.family)

    def frontend_param_count(self) -> int:
        return self.padded_vocab * self.d_model

    def head_param_count(self) -> int:
        p = self.d_model
        if not self.tie_embeddings:
            p += self.padded_vocab * self.d_model
        return p

    def total_param_count(self) -> int:
        return (
            self.frontend_param_count()
            + sum(self.unit_param_count(u) for u in range(self.n_units))
            + self.head_param_count()
        )

    def active_param_count(self) -> int:
        """Parameters active per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.total_param_count()
        ms = self.moe
        d, ff = self.d_model, self.d_ff
        inactive_per_moe = (ms.num_experts - ms.top_k) * 3 * d * ff
        if self.family == "moe":
            n_moe_layers = self.num_layers
        elif self.family == "hybrid":
            n_moe_layers = self.num_layers // self.moe_period
        else:
            n_moe_layers = 0
        return self.total_param_count() - n_moe_layers * inactive_per_moe

    def unit_flops_fwd(self, unit: int, batch: int, seq: int) -> float:
        """Forward FLOPs of one unit on [batch, seq] tokens (matmul-dominant)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        h, k = self.num_heads, self.num_kv_heads
        T = batch * seq
        ctx = min(seq, self.window) if self.window else seq

        def attn_flops(s_kv: int) -> float:
            proj = 2.0 * T * (d * h * hd + 2 * d * k * hd + h * hd * d)
            scores = 2.0 * batch * seq * s_kv * h * hd * 2
            return proj + scores

        def mlp_flops(width: int) -> float:
            return 2.0 * T * 3 * d * width

        def moe_flops(ms: MoeSpec) -> float:
            return 2.0 * T * d * ms.num_experts + ms.top_k * mlp_flops(ff)

        def mamba_flops(ss: SsmSpec) -> float:
            di = ss.expand * d
            nh = di // ss.head_dim
            proj = 2.0 * T * d * (2 * di + 2 * ss.state_dim + nh) + 2.0 * T * di * d
            q = ss.chunk
            nchunks = max(seq // q, 1)
            intra = 2.0 * batch * nchunks * q * q * (ss.state_dim + ss.head_dim) * nh
            inter = 4.0 * batch * nchunks * q * nh * ss.head_dim * ss.state_dim
            return proj + intra + inter

        if self.family in ("dense", "vlm"):
            return attn_flops(ctx) + mlp_flops(ff)
        if self.family == "moe":
            return attn_flops(ctx) + moe_flops(self.moe)
        if self.family == "ssm":
            return mamba_flops(self.ssm)
        if self.family == "hybrid":
            per = self.attn_period
            n_moe = per // self.moe_period
            return (
                attn_flops(ctx)
                + (per - 1) * mamba_flops(self.ssm)
                + n_moe * moe_flops(self.moe)
                + (per - n_moe) * mlp_flops(ff)
            )
        if self.family == "audio":
            if unit < self.encoder_layers:
                Te = batch * self.encoder_len
                return (
                    2.0 * Te * 4 * d * h * hd
                    + 2.0 * batch * self.encoder_len**2 * h * hd * 2
                    + 2.0 * Te * 3 * d * ff
                )
            cross = 2.0 * T * 4 * d * h * hd + 2.0 * batch * seq * self.encoder_len * h * hd * 2
            return attn_flops(ctx) + cross + mlp_flops(ff)
        raise ValueError(self.family)

    def unit_act_bytes(self, batch: int, seq: int, bytes_per: int = 2) -> int:
        """Bytes of the activation tensor crossing a cut boundary."""
        return batch * seq * self.d_model * bytes_per
