"""Deterministic fault model: what can go wrong, expanded per round.

A ``FaultSpec`` is the JSON-serializable description of a fleet's failure
regime — four orthogonal fault classes layered on top of whatever
``sim.scenarios`` regime the trace already carries:

* **crash**   — a client dies mid-round at a named split stage; its upload
  never reaches the server, so the round barrier excludes it (the partial
  chain work is wasted, recorded in telemetry, never waited on).
* **corrupt** — a client's uploaded replica is wrong: ``nan``/``inf``
  poison, a ``scale`` blow-up, or a ``bitflip`` in the exponent bits.
  Timing is unaffected (the bytes arrive on schedule); the guard path in
  ``tiers.synchronize`` is what catches these (DESIGN.md §16).
* **link**    — transient link-layer failures: every link traversal
  independently fails with ``link_fail_rate`` and is retried up to
  ``link_retries`` times.  Realized retries scale the trace's per-round
  link multipliers; the *expected* attempt count prices the analytic
  tables (``retry_attempts``, threaded through ``core.latency``).
* **outage**  — a whole fed-server cell (a tier-``outage_tier`` entity)
  is down for a span of rounds: it contributes nothing to the tier's
  aggregation barrier and its clients reroute to sibling cells
  (``faults.reroute``).

Expansion is seeded exactly like the scenario library: round r's fault
draws come from ``np.random.default_rng([seed, r, FAULT_TAG + class])``,
so faults compose with any scenario without perturbing its streams, and
the event oracle / vectorized fleet path see identical fault-adjusted
states.  A spec with all rates zero and no outage is *null*: every
composition hook returns its input unchanged (bit-for-bit).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

# Stream tags: scenarios use 0–4 (+16 for flaky-wan block outages); faults
# get their own block far away so composing never collides.
FAULT_TAG = 32
_CRASH_STREAM = 0
_CORRUPT_STREAM = 1
_LINK_STREAM = 2

CORRUPT_MODES = ("nan", "inf", "scale", "bitflip")
CRASH_STAGES = ("compute_fwd", "uplink", "compute_bwd", "downlink")


@dataclass(frozen=True)
class FaultSpec:
    """Seeded, JSON-round-trippable fault regime (all classes optional)."""

    seed: int = 0
    crash_rate: float = 0.0            # per-client per-round crash prob
    crash_stage: str = "uplink"        # named split stage the crash hits
    corrupt_rate: float = 0.0          # per-client per-round corruption prob
    corrupt_mode: str = "nan"          # nan | inf | scale | bitflip
    corrupt_scale: float = 1e6         # multiplier for mode="scale"
    link_fail_rate: float = 0.0        # per-traversal failure prob
    link_retries: int = 2              # retry cap per traversal
    outage_cells: Tuple[int, ...] = () # dead tier-`outage_tier` entities
    outage_tier: int = 1               # which tier's fed cells go dark
    outage_start: int = 0              # first outage round
    outage_len: int = 0                # 0 = no outage

    def __post_init__(self):
        object.__setattr__(
            self, "outage_cells", tuple(int(c) for c in self.outage_cells)
        )
        for name in ("crash_rate", "corrupt_rate", "link_fail_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must lie in [0, 1]: {v}")
        if self.link_fail_rate >= 1.0 and self.link_fail_rate > 0.0:
            raise ValueError(
                "link_fail_rate must be < 1 (a link that always fails has "
                "no finite expected traversal count)"
            )
        if self.crash_stage not in CRASH_STAGES:
            raise ValueError(
                f"crash_stage must be one of {CRASH_STAGES}: "
                f"{self.crash_stage!r}"
            )
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(
                f"corrupt_mode must be one of {CORRUPT_MODES}: "
                f"{self.corrupt_mode!r}"
            )
        if self.corrupt_scale <= 0 or not np.isfinite(self.corrupt_scale):
            raise ValueError(
                f"corrupt_scale must be finite and > 0: {self.corrupt_scale}"
            )
        if self.link_retries < 0:
            raise ValueError(f"link_retries must be >= 0: {self.link_retries}")
        if self.outage_tier < 0:
            raise ValueError(f"outage_tier must be >= 0: {self.outage_tier}")
        if self.outage_len < 0 or self.outage_start < 0:
            raise ValueError(
                "outage_start/outage_len must be >= 0: "
                f"({self.outage_start}, {self.outage_len})"
            )
        if self.outage_len > 0 and not self.outage_cells:
            raise ValueError(
                "outage_len > 0 needs at least one cell in outage_cells"
            )

    @property
    def is_null(self) -> bool:
        """True when this spec injects nothing — every composition hook
        (``faulty_trace``, guard masks, retry pricing, q-deflation) must
        then leave its input unchanged bit-for-bit."""
        return (
            self.crash_rate == 0.0
            and self.corrupt_rate == 0.0
            and self.link_fail_rate == 0.0
            and (self.outage_len == 0 or not self.outage_cells)
        )

    @property
    def has_outage(self) -> bool:
        return self.outage_len > 0 and bool(self.outage_cells)

    def outage_active(self, r: int) -> bool:
        """Whether the cell outage covers round r."""
        return (
            self.has_outage
            and self.outage_start <= r < self.outage_start + self.outage_len
        )

    @property
    def retry_mult(self) -> Optional[float]:
        """Expected link traversals per transfer (None when no failures —
        the gate that keeps the zero-fault pricing path untouched)."""
        if self.link_fail_rate == 0.0:
            return None
        return retry_attempts(self.link_fail_rate, self.link_retries)

    def validate_for(self, M: int, entities: Tuple[int, ...]) -> "FaultSpec":
        """Check the outage block against a concrete system topology."""
        if self.has_outage:
            if not 0 <= self.outage_tier < M - 1:
                raise ValueError(
                    f"outage_tier must name a fed-synced tier in "
                    f"[0, {M - 1}): {self.outage_tier}"
                )
            J = entities[self.outage_tier]
            bad = [c for c in self.outage_cells if not 0 <= c < J]
            if bad:
                raise ValueError(
                    f"outage_cells {bad} outside tier {self.outage_tier}'s "
                    f"entity range [0, {J})"
                )
            if len(set(self.outage_cells)) >= J:
                raise ValueError(
                    f"outage_cells kills all {J} tier-{self.outage_tier} "
                    "cells — no sibling left to reroute to"
                )
        return self

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["outage_cells"] = list(self.outage_cells)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultSpec":
        return cls(**{**d, "outage_cells": tuple(d.get("outage_cells", ()))})


def retry_attempts(fail_rate: float, retries: int) -> float:
    """Expected transmission attempts per link traversal, Σ_{a=0}^{k} p^a.

    Each attempt fails independently with probability p and is retried up
    to k times; the expected number of attempts made (stop at first
    success or after k+1 tries) is the truncated geometric series — the
    factor by which every priced link payload inflates (DESIGN.md §16).
    """
    if not 0.0 <= fail_rate < 1.0:
        raise ValueError(f"fail_rate must lie in [0, 1): {fail_rate}")
    if retries < 0:
        raise ValueError(f"retries must be >= 0: {retries}")
    p = float(fail_rate)
    return float(sum(p**a for a in range(int(retries) + 1)))


@dataclass(frozen=True)
class RoundFaults:
    """One round's realized faults (the per-round expansion of a spec).

    ``crashed``/``corrupt`` are [N] bool; ``attempts`` is the [N] realized
    transmission attempt count per client link traversal (all-ones when
    the link class is off); ``cell_out`` marks the outage span.
    """

    crashed: np.ndarray
    corrupt: np.ndarray
    attempts: np.ndarray
    cell_out: bool

    @property
    def faulty(self) -> np.ndarray:
        """[N] bool — clients whose round contribution is lost (crashed)
        or must be quarantined (corrupt): the mask q-deflation counts."""
        return self.crashed | self.corrupt

    @property
    def n_faulty(self) -> int:
        return int(np.count_nonzero(self.faulty))


def _stream(spec: FaultSpec, r: int, sub: int) -> np.random.Generator:
    return np.random.default_rng([spec.seed, r, FAULT_TAG + sub])


def expand_faults(spec: FaultSpec, r: int, num_clients: int) -> RoundFaults:
    """Round r's fault draws (deterministic in (seed, r); independent
    sub-streams per fault class, so enabling one class never perturbs
    another's draws)."""
    N = num_clients
    crashed = np.zeros(N, dtype=bool)
    corrupt = np.zeros(N, dtype=bool)
    attempts = np.ones(N)
    if spec.crash_rate > 0.0:
        crashed = _stream(spec, r, _CRASH_STREAM).random(N) < spec.crash_rate
    if spec.corrupt_rate > 0.0:
        corrupt = _stream(spec, r, _CORRUPT_STREAM).random(N) < spec.corrupt_rate
        corrupt &= ~crashed  # a crashed client uploads nothing to corrupt
    if spec.link_fail_rate > 0.0:
        attempts = realized_attempts(
            _stream(spec, r, _LINK_STREAM), spec, N
        )
    return RoundFaults(
        crashed=crashed,
        corrupt=corrupt,
        attempts=attempts,
        cell_out=spec.outage_active(r),
    )


def realized_attempts(
    rng: np.random.Generator, spec: FaultSpec, n: int
) -> np.ndarray:
    """[n] realized attempt counts: geometric (first-success) draws with
    success prob 1-p, capped at the retry budget ``link_retries + 1``."""
    draws = rng.geometric(1.0 - spec.link_fail_rate, n)
    return np.minimum(draws, spec.link_retries + 1).astype(np.float64)
