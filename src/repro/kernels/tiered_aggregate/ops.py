"""jit'd public wrappers: apply the fused aggregation to whole pytrees.

``aggregate_tree`` flattens a client-stacked pytree (leaves [N, ...]) into
one [N, P] buffer view per leaf, runs the kernel, and reassembles —
exactly what ``tiers.synchronize`` does per (tier, level), but in one fused
HBM pass per leaf. On CPU (tests / this container) ``interpret=True`` runs
the same kernel body in Python; on TPU set ``interpret=False``.

``tiered_aggregate_q8`` is the compressed-wire entry (DESIGN.md §9): it
takes the raw [N, P] shard, produces the int8-plus-per-tile-scale wire
payload via the shared ``compress.quantize`` codec, and runs the fused
dequantize→aggregate kernel over it — the HBM-heavy read is the int8
payload, ~4× less traffic than the f32 path.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ...compress.quantize import q8_dequantize, q8_quantize
from .ref import ragged_quantized_tiered_aggregate_ref, tiered_aggregate_ref
from .tiered_aggregate import (
    TILE_P,
    quantized_tiered_aggregate_pallas,
    ragged_quantized_tiered_aggregate_pallas,
    tiered_aggregate_pallas,
)


@partial(
    jax.jit, static_argnames=("num_entities", "tile_p", "use_pallas", "interpret")
)
def tiered_aggregate(
    x: jax.Array,
    weights: jax.Array,
    do_entity: jax.Array,
    do_global: jax.Array,
    num_entities: int,
    tile_p: int = TILE_P,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """[N, P] fused two-level aggregation (see ref.py for semantics)."""
    do_entity = jnp.asarray(do_entity)
    do_global = jnp.asarray(do_global)
    if use_pallas:
        return tiered_aggregate_pallas(
            x, weights, do_entity, do_global, num_entities,
            tile_p=tile_p, interpret=interpret,
        )
    return tiered_aggregate_ref(x, weights, do_entity, do_global, num_entities)


@partial(
    jax.jit, static_argnames=("num_entities", "tile_p", "use_pallas", "interpret")
)
def tiered_aggregate_q8(
    x: jax.Array,
    weights: jax.Array,
    do_entity: jax.Array,
    do_global: jax.Array,
    num_entities: int,
    tile_p: int = TILE_P,
    key: Optional[jax.Array] = None,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Quantize [N, P] to the q8 wire format, aggregate fused, return f32.

    ``key`` switches the codec to stochastic (unbiased) rounding; without
    it the path is deterministic, which is what the bit-for-bit oracle
    tests and the engine-equality tests pin.

    The ``use_pallas=False`` fallback dequantizes vectorized and reuses the
    f32 reference reduction (the per-tile ``ref.py`` loop is the *test
    oracle* — tracing it inside jit would unroll O(P/tile_p) subgraphs).
    """
    N, P = x.shape
    do_entity = jnp.asarray(do_entity)
    do_global = jnp.asarray(do_global)
    q, scales = q8_quantize(x.astype(jnp.float32), tile_p, key=key)
    if use_pallas:
        out = quantized_tiered_aggregate_pallas(
            q, scales, weights, do_entity, do_global, num_entities,
            tile_p=tile_p, interpret=interpret,
        )
    else:
        deq = q8_dequantize(q, scales, tile_p)
        out = tiered_aggregate_ref(
            deq, weights, do_entity, do_global, num_entities
        )
    return out[:, :P]


@partial(
    jax.jit, static_argnames=("num_entities", "tile_p", "use_pallas", "interpret")
)
def ragged_tiered_aggregate_q8(
    x: jax.Array,
    weights: jax.Array,
    member: jax.Array,
    do_entity: jax.Array,
    do_global: jax.Array,
    num_entities: int,
    tile_p: int = TILE_P,
    key: Optional[jax.Array] = None,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Ragged (per-class cut) q8 aggregation of an [N, P] unit-range shard.

    ``member`` [N] marks the clients whose class holds this shard's units
    in the aggregating tier (``tiers.class_tier_members`` column); they
    alone feed and receive the two reduction levels.  All-ones member with
    normalized weights reproduces ``tiered_aggregate_q8`` bit-for-bit.
    The ``use_pallas=False`` fallback dequantizes vectorized and applies
    the member-masked reduction in one pass (the per-tile ``ref.py`` loop
    stays the test oracle).
    """
    N, P = x.shape
    do_entity = jnp.asarray(do_entity)
    do_global = jnp.asarray(do_global)
    q, scales = q8_quantize(x.astype(jnp.float32), tile_p, key=key)
    if use_pallas:
        out = ragged_quantized_tiered_aggregate_pallas(
            q, scales, weights, member, do_entity, do_global, num_entities,
            tile_p=tile_p, interpret=interpret,
        )
    else:
        deq = q8_dequantize(q, scales, tile_p)
        J = num_entities
        per = N // J
        m = member.astype(jnp.float32)[:, None]
        grouped = deq.reshape(J, per, -1)
        mg = m.reshape(J, per, 1)
        sg = jnp.sum(mg, axis=1, keepdims=True)
        emean = jnp.sum(grouped * mg, axis=1, keepdims=True) / jnp.maximum(
            sg, 1.0
        )
        emean = jnp.broadcast_to(emean, grouped.shape).reshape(deq.shape)
        sg_rows = jnp.broadcast_to(sg, grouped.shape).reshape(deq.shape)
        y1 = jnp.where(do_entity & (m > 0.0) & (sg_rows > 0.0), emean, deq)
        wm = weights.astype(jnp.float32)[:, None] * m
        sw = jnp.sum(wm, axis=0, keepdims=True)
        gmean = jnp.sum(y1 * wm, axis=0, keepdims=True) / jnp.where(
            sw > 0.0, sw, 1.0
        )
        out = jnp.where(
            do_global & (m > 0.0) & (sw > 0.0),
            jnp.broadcast_to(gmean, y1.shape),
            y1,
        )
    return out[:, :P]


def aggregate_tree(
    tree: Any,
    weights: jax.Array,
    do_entity: jax.Array,
    do_global: jax.Array,
    num_entities: int,
    tile_p: int = TILE_P,
    use_pallas: bool = True,
    interpret: bool = True,
    quantized: bool = False,
) -> Any:
    """Apply the fused aggregation leaf-wise to a client-stacked pytree.

    ``quantized=True`` routes every leaf through the q8 wire (the MA
    hot-spot at ~4× lower HBM traffic); outputs are cast back to the leaf
    dtype.  ``tile_p`` is both the kernel tile AND the codec's scale-tile —
    pass the same value the analytic layer priced (``Int8Stochastic.tile``)
    so the executed ω matches the Theorem-1 inflation.
    """

    def f(x):
        n = x.shape[0]
        flat = x.reshape(n, -1)
        if quantized:
            out = tiered_aggregate_q8(
                flat, weights, do_entity, do_global, num_entities,
                tile_p=tile_p, use_pallas=use_pallas, interpret=interpret,
            ).astype(x.dtype)
        else:
            out = tiered_aggregate(
                flat, weights, do_entity, do_global, num_entities,
                tile_p=tile_p, use_pallas=use_pallas, interpret=interpret,
            )
        return out.reshape(x.shape)

    return jax.tree.map(f, tree)
