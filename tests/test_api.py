"""repro.api: spec round-trips, registry coverage, build equivalence.

Four contracts:

1. **Lossless serialization** — ``from_dict(to_dict(spec)) == spec`` (and
   through a real JSON string) for specs exercising every registry entry:
   all model ids, system presets, scenarios, and codecs.
2. **Bit-exact equivalence** — ``api.build()`` composes exactly the same
   problem the manual ``HsflProblem`` + ``with_compression`` +
   ``robust_problem`` wiring produced: identical Θ′, latency terms, and
   identical ``solve_bcd`` output.
3. **The footgun is unrepresentable** — a spec carrying both compression
   and a scenario builds (and solves) fine, while the equivalent manual
   mis-ordering still raises in ``core.problem``; ``build`` covers the
   previously-raising path.
4. **Reproducibility from disk** — serializing a spec to JSON, reloading,
   and re-running yields an identical ``ExperimentResult`` (schedule, Θ′,
   R-to-ε).
"""
import json

import numpy as np
import pytest

from repro.api import (
    CODECS,
    MODEL_IDS,
    SYSTEMS,
    CompressionCfg,
    ExperimentSpec,
    HyperCfg,
    ModelCfg,
    RunCfg,
    ScenarioCfg,
    SolverCfg,
    SystemCfg,
    build,
    evaluate_schedule,
    get_experiment,
    paper_spec,
    quickstart_spec,
    robust_spec,
    run,
    scenario_names,
    tpu_pod_spec,
    two_tier_spec,
)
from repro.api.presets import EXPERIMENTS


def roundtrip(spec: ExperimentSpec) -> ExperimentSpec:
    """to_dict -> real JSON string -> from_dict."""
    return ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))


# --------------------------------------------------------------------------- #
# 1. lossless serialization over every registry entry
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("arch", MODEL_IDS)
def test_roundtrip_every_model(arch):
    spec = ExperimentSpec(model=ModelCfg(arch=arch, variant="reduced", batch=4))
    assert roundtrip(spec) == spec


@pytest.mark.parametrize("preset", sorted(SYSTEMS))
def test_roundtrip_every_system(preset):
    spec = ExperimentSpec(
        system=SystemCfg(preset=preset, num_clients=20, num_edges=5, seed=3)
    )
    assert roundtrip(spec) == spec


@pytest.mark.parametrize("name", scenario_names())
def test_roundtrip_every_scenario(name):
    spec = ExperimentSpec(
        scenario=ScenarioCfg(name=name, rounds=8, seed=1, quantile=0.5)
    )
    assert roundtrip(spec) == spec


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_roundtrip_every_codec(codec):
    spec = ExperimentSpec(compression=CompressionCfg(codec=codec))
    assert roundtrip(spec) == spec


@pytest.mark.parametrize("name", sorted(EXPERIMENTS))
def test_roundtrip_every_experiment_preset(name):
    spec = get_experiment(name)
    assert roundtrip(spec) == spec


def test_roundtrip_full_spec_with_everything():
    spec = ExperimentSpec(
        name="kitchen-sink",
        model=ModelCfg(arch="smollm-135m", variant="reduced", num_layers=4,
                       batch=4, seq=32, optimizer="adam"),
        system=SystemCfg(preset="paper-three-tier", num_clients=8, num_edges=4,
                         seed=7, comm_scale=0.5, extras={"memory_bytes": 8e9}),
        hyper=HyperCfg(beta=3.0, eps_scale=5.0, seed=7),
        scenario=ScenarioCfg(name="flaky-wan", rounds=16, quantile=0.9,
                             params={"outage_p": 0.1}),
        compression=CompressionCfg(codec="int8", params={"tile": 128},
                                   model_ratio=(0.5, 0.25)),
        solver=SolverCfg(kind="bcd", cuts=(2, 4), intervals=(2, 2, 1)),
        run=RunCfg(mode="solve", seed=7),
    )
    rt = roundtrip(spec)
    assert rt == spec
    # tuple fields stay tuples after the JSON list round-trip
    assert isinstance(rt.solver.cuts, tuple)
    assert isinstance(rt.compression.model_ratio, tuple)


def test_unknown_names_raise_with_choices():
    with pytest.raises(KeyError, match="paper-three-tier"):
        build(ExperimentSpec(system=SystemCfg(preset="nope")))
    with pytest.raises(KeyError, match="int8"):
        build(ExperimentSpec(compression=CompressionCfg(codec="nope")))
    with pytest.raises(KeyError, match="unknown arch"):
        build(ExperimentSpec(model=ModelCfg(arch="nope")))
    with pytest.raises(ValueError, match="accepted"):
        build(
            ExperimentSpec(
                scenario=ScenarioCfg(name="flaky-wan", rounds=4,
                                     params={"bogus_knob": 1.0})
            )
        )


# --------------------------------------------------------------------------- #
# 2. bit-exact equivalence with the manual wiring
# --------------------------------------------------------------------------- #


def manual_paper_problem(seed=0, eps_scale=6.0):
    from repro.configs.vgg16_cifar10 import SPEC as VGG
    from repro.core import (
        HsflProblem, SystemSpec, build_profile, synthetic_hyperspec,
    )
    from repro.core.convergence import theorem1_bound

    prof = build_profile(VGG, batch=16)
    system = SystemSpec.paper_three_tier(seed=seed)
    hp = synthetic_hyperspec(VGG.n_units, 20, beta=3.0, seed=seed)
    floor = theorem1_bound(hp, 10**9, [1, 1, 1], (3, 8))
    return HsflProblem(prof, system, hp, eps=eps_scale * floor)


def test_build_matches_manual_problem_exactly():
    from repro.core import solve_bcd

    manual = manual_paper_problem(seed=0)
    api_prob = build(paper_spec(seed=0)).problem
    assert api_prob.eps == manual.eps
    sched = ((2, 5, 1), (3, 8))
    assert api_prob.theta(*sched) == manual.theta(*sched)
    assert api_prob.split_T((3, 8)) == manual.split_T((3, 8))
    np.testing.assert_array_equal(api_prob.agg_T((3, 8)), manual.agg_T((3, 8)))

    res_a, res_m = solve_bcd(api_prob), solve_bcd(manual)
    assert res_a.cuts == res_m.cuts
    assert tuple(res_a.intervals) == tuple(res_m.intervals)
    assert res_a.theta == res_m.theta
    assert res_a.total_latency == res_m.total_latency


def test_build_compressed_matches_manual_with_compression():
    from repro.compress import CompressionSpec
    from repro.core import solve_bcd

    manual = manual_paper_problem(seed=0).with_compression(
        CompressionSpec.uniform(3, model_ratio=0.25)
    )
    spec = paper_spec(seed=0).replace(
        compression=CompressionCfg(codec="identity", model_ratio=0.25)
    )
    api_prob = build(spec).problem
    assert api_prob.compression == manual.compression
    res_a, res_m = solve_bcd(api_prob), solve_bcd(manual)
    assert (res_a.cuts, tuple(res_a.intervals), res_a.theta) == (
        res_m.cuts, tuple(res_m.intervals), res_m.theta
    )


def test_build_robust_matches_manual_robust_problem():
    from repro.core import solve_bcd
    from repro.sim import make_trace, robust_problem

    manual_base = manual_paper_problem(seed=0)
    trace = make_trace(
        "straggler-tail", manual_base.profile, manual_base.system,
        rounds=16, seed=0,
    )
    manual = robust_problem(manual_base, trace, quantile=0.95)

    spec = robust_spec("straggler-tail", seed=0, rounds=16, quantile=0.95)
    api_prob = build(spec).problem
    assert api_prob.split_T((3, 8)) == manual.split_T((3, 8))
    res_a, res_m = solve_bcd(api_prob), solve_bcd(manual)
    assert (res_a.cuts, tuple(res_a.intervals), res_a.theta) == (
        res_m.cuts, tuple(res_m.intervals), res_m.theta
    )


def test_build_covers_the_previously_raising_path():
    """compression + scenario in one spec builds and solves; the manual
    mis-ordering (compression under an attached latency model) still
    raises with a pointer at api.build."""
    from repro.compress import CompressionSpec
    from repro.sim import make_trace, robust_problem

    spec = paper_spec(seed=0).replace(
        compression=CompressionCfg(codec="identity", model_ratio=0.25),
        scenario=ScenarioCfg(name="straggler-tail", rounds=8, quantile=0.95),
    )
    built = build(spec)  # must not raise
    assert built.problem.compression is not None
    assert built.problem.latency_model is not None
    # the trace was re-priced over the same wire
    assert built.trace.compression == built.problem.compression
    res = run(spec)
    assert np.isfinite(res.theta)

    # the footgun, expressed manually, still raises — and names the api
    manual_base = manual_paper_problem(seed=0)
    trace = make_trace(
        "straggler-tail", manual_base.profile, manual_base.system,
        rounds=8, seed=0,
    )
    robust = robust_problem(manual_base, trace, quantile=0.95)
    with pytest.raises(ValueError, match="repro.api.build"):
        robust.with_compression(CompressionSpec.uniform(3, model_ratio=0.25))


def test_system_preset_validation():
    # client-cloud has exactly one server; a spec claiming otherwise raises
    with pytest.raises(ValueError, match="num_edges=1"):
        build(ExperimentSpec(
            system=SystemCfg(preset="two-tier-client-cloud", num_edges=7)
        ))
    # more edges than clients cannot host a split
    with pytest.raises(ValueError, match="num_edges <= num_clients"):
        build(ExperimentSpec(
            system=SystemCfg(preset="two-tier-client-edge",
                             num_clients=20, num_edges=30)
        ))
    # two-tier presets take no extras (nothing would consume them)
    with pytest.raises(ValueError, match="takes no extras"):
        build(ExperimentSpec(
            system=SystemCfg(preset="two-tier-client-edge",
                             extras={"memory_bytes": 1e9})
        ))


def test_train_mode_rejects_unpriced_seq():
    # LM training at the seq=1 default would diverge from the priced shape
    spec = ExperimentSpec(
        model=ModelCfg(arch="smollm-135m", variant="reduced", num_layers=4,
                       batch=4),
        system=SystemCfg(preset="paper-three-tier", num_clients=8, num_edges=4),
        solver=SolverCfg(kind="fixed", cuts=(1, 3), intervals=(4, 2, 1)),
        run=RunCfg(mode="train", rounds=1),
    )
    with pytest.raises(ValueError, match="seq >= 2"):
        run(spec)


def test_run_accepts_prebuilt_and_rejects_mismatch():
    spec = paper_spec(seed=0)
    built = build(spec)
    res = run(spec, built=built)
    assert identity_result_fields(res) == identity_result_fields(run(spec))
    with pytest.raises(ValueError, match="different spec"):
        run(paper_spec(seed=1), built=built)


def test_two_tier_and_tpu_presets_build_and_solve():
    for spec in (
        two_tier_spec("client-edge", seed=0),
        two_tier_spec("client-cloud", seed=0),
        tpu_pod_spec(seed=0, eps=2.0),
    ):
        res = run(spec)
        assert np.isfinite(res.theta)
        assert len(res.cuts) == build(spec).system.M - 1


# --------------------------------------------------------------------------- #
# 3. run(spec) reproducibility from disk
# --------------------------------------------------------------------------- #


def identity_result_fields(res):
    return (res.cuts, res.intervals, res.theta, res.rounds_to_eps,
            res.total_latency)


def test_json_spec_reproduces_identical_result(tmp_path):
    spec = paper_spec(seed=0)
    res = run(spec)

    path = tmp_path / "spec.json"
    path.write_text(json.dumps(spec.to_dict()))
    reloaded = ExperimentSpec.from_dict(json.loads(path.read_text()))
    assert reloaded == spec
    res2 = run(reloaded)
    assert identity_result_fields(res2) == identity_result_fields(res)
    assert res2.latency == res.latency


def test_json_spec_reproduces_robust_result(tmp_path):
    spec = robust_spec("flaky-wan", seed=1, rounds=8)
    res = run(spec)
    reloaded = ExperimentSpec.from_dict(
        json.loads(json.dumps(spec.to_dict()))
    )
    res2 = run(reloaded)
    assert identity_result_fields(res2) == identity_result_fields(res)


def test_result_to_dict_is_json_and_roundtrips():
    from repro.api import ExperimentResult

    res = run(paper_spec(seed=0))
    s = json.dumps(res.to_dict())  # must not raise (numpy coerced)
    back = ExperimentResult.from_dict(json.loads(s))
    assert identity_result_fields(back) == identity_result_fields(res)
    # provenance alone is enough to re-run the experiment
    res3 = run(ExperimentSpec.from_dict(back.provenance))
    assert identity_result_fields(res3) == identity_result_fields(res)


def test_solver_kinds_dispatch():
    base = paper_spec(seed=0)
    bcd = run(base)
    ma = run(base.replace(solver=SolverCfg(kind="ma", cuts=bcd.cuts)))
    assert ma.cuts == bcd.cuts
    ms = run(base.replace(
        solver=SolverCfg(kind="ms", intervals=bcd.intervals)
    ))
    assert ms.intervals == bcd.intervals
    fixed = run(base.replace(
        solver=SolverCfg(kind="fixed", cuts=bcd.cuts, intervals=bcd.intervals)
    ))
    assert identity_result_fields(fixed)[:2] == identity_result_fields(bcd)[:2]
    assert fixed.theta == bcd.theta
    with pytest.raises(ValueError, match="solver.cuts"):
        run(base.replace(solver=SolverCfg(kind="ma")))


def test_simulate_mode_profiles_the_schedule():
    spec = robust_spec("lognormal-heterogeneous", seed=0, rounds=8).replace(
        run=RunCfg(mode="simulate", seed=0)
    )
    res = run(spec)
    assert res.sim is not None
    assert res.sim["rounds"] == 8
    assert res.sim["total_p95"] >= res.sim["total_p50"] > 0
    assert res.sim["mean_participants"] > 0


def test_evaluate_schedule_matches_run():
    spec = paper_spec(seed=0)
    res = run(spec)
    ev = evaluate_schedule(build(spec), res.cuts, res.intervals)
    assert identity_result_fields(ev) == identity_result_fields(res)


# --------------------------------------------------------------------------- #
# 4. training path + deprecation shim
# --------------------------------------------------------------------------- #


@pytest.mark.slow
def test_train_mode_quickstart_runs_and_learns():
    res = run(quickstart_spec(rounds=8))
    assert res.train is not None
    assert res.train["final_loss"] < res.train["first_loss"]
    assert np.isfinite(res.train["thm1_bound"])


def test_common_paper_problem_shim_retired():
    # the deprecated hand-wired constructor is gone; the API preset is the
    # one way to build the Sec. VII problem (build(paper_spec(...)).problem)
    import benchmarks.common as common

    assert not hasattr(common, "paper_problem")
    prob = build(paper_spec(seed=0)).problem
    manual = manual_paper_problem(seed=0)
    assert prob.eps == manual.eps
    assert prob.theta((2, 5, 1), (3, 8)) == manual.theta((2, 5, 1), (3, 8))


def test_top_level_package_exports_api():
    import repro

    assert repro.api.ExperimentSpec is ExperimentSpec


def test_every_lazy_submodule_imports():
    # satellite of DESIGN.md §15: repro.__init__ lazily exposes submodules;
    # each advertised name must import and be a real module
    import importlib
    import types

    import repro

    for name in repro._SUBMODULES:
        mod = getattr(repro, name)
        assert isinstance(mod, types.ModuleType), name
        assert mod is importlib.import_module(f"repro.{name}"), name
    assert {"privacy", "energy", "control"} <= set(repro._SUBMODULES)


# --------------------------------------------------------------------------- #
# 5. per-class cut assignment (DESIGN.md §14)
# --------------------------------------------------------------------------- #


def test_classes_cfg_validation():
    from repro.api import ClassesCfg

    with pytest.raises(ValueError, match="num_classes"):
        ClassesCfg(num_classes=0)
    with pytest.raises(ValueError, match="compute|uplink|explicit"):
        ClassesCfg(by="nope")
    with pytest.raises(ValueError, match="exactly when"):
        ClassesCfg(by="compute", assign=(0, 1))
    with pytest.raises(ValueError, match="exactly when"):
        ClassesCfg(by="explicit")
    with pytest.raises(ValueError, match="product_budget"):
        ClassesCfg(product_budget=0)


def test_classes_section_roundtrips():
    from repro.api import ClassesCfg, hetcuts_spec

    spec = hetcuts_spec(num_classes=4, by="uplink", seed=3)
    rt = roundtrip(spec)
    assert rt == spec
    explicit = tpu_pod_spec().replace(
        classes=ClassesCfg(
            num_classes=2, by="explicit",
            assign=tuple(i % 2 for i in range(16)),
        )
    )
    rt = roundtrip(explicit)
    assert rt == explicit
    assert isinstance(rt.classes.assign, tuple)


def test_classes_conflicts_and_guards():
    from repro.api import ClassesCfg, ParticipationCfg, hetcuts_spec

    cc = ClassesCfg(num_classes=2, by="compute")
    with pytest.raises(ValueError, match="nominal pricing"):
        build(paper_spec().replace(
            classes=cc, scenario=ScenarioCfg(name="flaky-wan", rounds=4)
        ))
    with pytest.raises(ValueError, match="nominal pricing"):
        build(paper_spec().replace(
            classes=cc, participation=ParticipationCfg(target_rate=0.5)
        ))
    with pytest.raises(ValueError, match="per client"):
        build(tpu_pod_spec().replace(
            classes=ClassesCfg(num_classes=2, by="explicit", assign=(0, 1))
        ))
    spec = hetcuts_spec(num_classes=2)
    with pytest.raises(ValueError, match="bcd"):
        run(spec.replace(solver=SolverCfg(kind="ms")))
    with pytest.raises(ValueError, match="solve"):
        run(spec.replace(run=RunCfg(mode="train")))


def test_classes_build_resolves_assignment():
    from repro.api import hetcuts_spec
    from repro.core.classes import banded_assignment

    built = build(hetcuts_spec(num_classes=2, by="uplink", seed=0))
    assert built.class_spec is not None
    expect = banded_assignment(built.problem.system.model_up[0], 2)
    assert built.class_spec.class_of == tuple(int(c) for c in expect)
    assert built.class_spec.is_uniform()  # every class starts at the anchor
