"""Serve an HSFL-trained model with batched autoregressive decoding.

After training, the fed server owns the aggregated model; this example
restores a checkpoint (or initializes fresh weights), then decodes a batch
of requests against a KV/state cache - the same ``decode_step`` that the
decode_32k / long_500k dry-runs lower onto the production mesh.

    PYTHONPATH=src python examples/serve_hsfl.py                       # qwen2 reduced
    PYTHONPATH=src python examples/serve_hsfl.py --arch mamba2-1.3b    # SSM decode
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "qwen2-1.5b",
        "--batch", "4",
        "--prompt-len", "8",
        "--gen", "24",
        "--cache-len", "64",
        "--temperature", "0.8",
    ]
    raise SystemExit(main(argv))
