"""Benchmark runner (deliverable d): one harness per paper table/figure,
plus the roofline extraction over the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-training]

Harness -> paper artifact map (details in DESIGN.md sect. 7):
    fig2_latency_vs_cut   Fig. 2(c)  per-round latency vs cut layer
    fig45_benchmarks      Figs. 4-5  HSFL vs the 5 baseline policies
    fig67_resources       Figs. 6-7  resource scaling + tier count
    ablations             Figs. 8-9  MA / MS ablations (+ real training)
    bound_check           Thm 1      empirical gradient norms vs the bound
    roofline              sect. g    three-term roofline per (arch x shape)
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grids / fewer training rounds")
    ap.add_argument("--skip-training", action="store_true",
                    help="skip the real-training ablation/bound harnesses")
    ap.add_argument("--only", default=None, help="run a single harness")
    args = ap.parse_args(argv)

    from . import ablations, bound_check, fig2_latency_vs_cut, fig45_benchmarks
    from . import fig67_resources, roofline

    analytic = [
        ("fig2_latency_vs_cut", lambda: fig2_latency_vs_cut.main(args.quick)),
        ("fig45_benchmarks", lambda: fig45_benchmarks.main(args.quick)),
        ("fig67_resources", lambda: fig67_resources.main(args.quick)),
    ]
    training = [
        ("ablations", lambda: ablations.main(args.quick)),
        ("bound_check", lambda: bound_check.main(args.quick)),
    ]
    extracted = [
        ("roofline", lambda: roofline.main(
            ["--csv", "experiments/roofline_16x16.csv"])),
    ]

    jobs = analytic + ([] if args.skip_training else training) + extracted
    if args.only:
        jobs = [(n, f) for n, f in jobs if n == args.only]
        if not jobs:
            print(f"unknown harness {args.only!r}", file=sys.stderr)
            return 2

    failures = []
    for name, fn in jobs:
        print(f"\n{'='*70}\n== {name}\n{'='*70}")
        t0 = time.time()
        try:
            fn()
            print(f"-- {name} ok ({time.time()-t0:.1f}s)")
        except Exception as e:  # keep going; report at the end
            failures.append((name, repr(e)))
            print(f"-- {name} FAILED: {e!r}", file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} harness(es) failed: {failures}", file=sys.stderr)
        return 1
    print(f"\nall {len(jobs)} harnesses passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
