"""Theorem 1 / Corollary 1 behaviour."""
import numpy as np
import pytest

from repro.core.convergence import (
    HyperSpec, corollary1_rounds, synthetic_hyperspec, theorem1_bound,
    tier_G2_sums, bound_constants, stale_interval_weights, staleness_rounds,
)


@pytest.fixture
def hp():
    return synthetic_hyperspec(n_units=12, num_clients=20, beta=5.0, seed=0)


def test_tier_g2_sums(hp):
    d = tier_G2_sums(hp.G2, (3, 8))
    assert d.shape == (3,)
    np.testing.assert_allclose(d.sum(), hp.G2.sum(), rtol=1e-12)
    np.testing.assert_allclose(d[0], hp.G2[:3].sum(), rtol=1e-12)


def test_bound_monotone_in_intervals(hp):
    """Insight 1: shorter aggregation intervals tighten the bound."""
    prev = None
    for I1 in [1, 2, 4, 8, 16]:
        b = theorem1_bound(hp, R=1000, intervals=[I1, 2, 1], cuts=(4, 8))
        if prev is not None:
            assert b >= prev
        prev = b


def test_bound_monotone_in_rounds(hp):
    bs = [theorem1_bound(hp, R, [2, 2, 1], (4, 8)) for R in [10, 100, 1000]]
    assert bs[0] > bs[1] > bs[2]


def test_bound_indicator_at_one(hp):
    """I=1 tiers contribute no drift term (the 1{I>1} indicator)."""
    b1 = theorem1_bound(hp, 100, [1, 1, 1], (4, 8))
    # residual = first two terms only
    c, kappa = bound_constants(hp, 0.0)
    expected = 2 * hp.theta0 / (hp.gamma * 100) + (-c)
    np.testing.assert_allclose(b1, expected, rtol=1e-9)


def test_cut_shifts_g2_between_tiers(hp):
    """Insight 2: moving the cut moves G_l^2 mass between interval classes."""
    deep = theorem1_bound(hp, 1000, [8, 1, 1], (10, 11))
    shallow = theorem1_bound(hp, 1000, [8, 1, 1], (1, 11))
    # deeper cut_1 puts more layers under the slow I=8 tier -> looser bound
    assert deep > shallow


def test_corollary_rounds(hp):
    eps = theorem1_bound(hp, 500, [2, 2, 1], (4, 8))
    R = corollary1_rounds(hp, eps, [2, 2, 1], (4, 8))
    np.testing.assert_allclose(R, 500, rtol=1e-6)
    assert corollary1_rounds(hp, 1e-12, [2, 2, 1], (4, 8)) is None


# --------------------------------------------------------------------------- #
# bounded-staleness pricing (DESIGN.md §17)
# --------------------------------------------------------------------------- #


def test_staleness_zero_collapses_bitexact(hp):
    """s ≡ 0 must evaluate the exact pre-async float expression."""
    base = theorem1_bound(hp, 500, [4, 2, 1], (4, 8))
    assert theorem1_bound(hp, 500, [4, 2, 1], (4, 8), staleness=0) == base
    assert theorem1_bound(hp, 500, [4, 2, 1], (4, 8), staleness=None) == base
    assert (
        theorem1_bound(hp, 500, [4, 2, 1], (4, 8), staleness=[0, 0, 0]) == base
    )
    R = corollary1_rounds(hp, base, [4, 2, 1], (4, 8), staleness=0)
    np.testing.assert_allclose(R, 500, rtol=1e-6)


def test_staleness_inflates_monotonically(hp):
    prev = theorem1_bound(hp, 500, [4, 2, 1], (4, 8))
    for s in (1, 2, 4, 8):
        b = theorem1_bound(hp, 500, [4, 2, 1], (4, 8), staleness=(s, 0, 0))
        assert b > prev
        prev = b
    # a stale sync needs more rounds to hit the same target eps
    eps = theorem1_bound(hp, 500, [4, 2, 1], (4, 8))
    R0 = corollary1_rounds(hp, 1.01 * eps, [4, 2, 1], (4, 8))
    R1 = corollary1_rounds(hp, 1.01 * eps, [4, 2, 1], (4, 8),
                           staleness=(1, 0, 0))
    assert R1 is None or R1 > R0


def test_staleness_drift_matches_interval_inflation(hp):
    """The stale drift weight is exactly (I+s)²: a tier at (I, s) prices
    identically to the synchronous tier at interval I+s."""
    b_async = theorem1_bound(hp, 500, [4, 2, 1], (4, 8), staleness=(3, 0, 0))
    b_sync = theorem1_bound(hp, 500, [7, 2, 1], (4, 8))
    np.testing.assert_allclose(b_async, b_sync, rtol=1e-12)


def test_stale_interval_weights():
    w = stale_interval_weights([4, 2, 1])
    np.testing.assert_allclose(w, [16.0, 4.0, 0.0])
    np.testing.assert_allclose(
        stale_interval_weights([4, 2, 1], (0, 0, 0)), w
    )
    w2 = stale_interval_weights([4, 2, 1], (3, 0, 0))
    np.testing.assert_allclose(w2, [49.0, 4.0, 0.0])
    # an I=1 tier landing s rounds late drifts the full (1+s)^2
    np.testing.assert_allclose(
        stale_interval_weights([1, 2, 1], (2, 0, 0)), [9.0, 4.0, 0.0]
    )


def test_staleness_rounds_validation():
    np.testing.assert_array_equal(staleness_rounds(None, 3), [0, 0, 0])
    np.testing.assert_array_equal(staleness_rounds(2, 3), [2, 2, 2])
    np.testing.assert_array_equal(staleness_rounds((1, 0, 0), 3), [1, 0, 0])
    with pytest.raises(ValueError, match="per-tier staleness"):
        staleness_rounds((1, 0), 3)
    with pytest.raises(ValueError, match=">= 0"):
        staleness_rounds((-1, 0, 0), 3)


@pytest.mark.parametrize("seed", range(8))
def test_bound_positive_property(seed):
    hp = synthetic_hyperspec(10, 16, seed=seed)
    rng = np.random.default_rng(seed)
    I = [int(rng.integers(1, 30)), int(rng.integers(1, 30)), 1]
    cuts = tuple(sorted(rng.integers(0, 11, 2)))
    assert theorem1_bound(hp, int(rng.integers(1, 10**6)), I, cuts) > 0
