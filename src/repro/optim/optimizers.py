"""Minimal optimizer library (optax is not available offline).

Optimizers are (init, update) pairs over pytrees. The HSFL memory constraint
C5 prices optimizer state, so each optimizer reports bytes-per-parameter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Any


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState], Tuple[Params, OptState]]
    state_bytes_per_param: float  # for constraint C5


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(params, grads, state):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return Optimizer("sgd", init, update, 0.0)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(params, grads, state):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype), state, grads)
        new_p = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype), params, new_m)
        return new_p, new_m

    return Optimizer("momentum", init, update, 4.0)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z), "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        t = state["t"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        tf = t.astype(jnp.float32)
        c1 = 1.0 - b1**tf
        c2 = 1.0 - b2**tf
        new_p = jax.tree.map(
            lambda p, m_, v_: p
            - (lr * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)).astype(p.dtype),
            params, m, v,
        )
        return new_p, {"m": m, "v": v, "t": t}

    return Optimizer("adam", init, update, 8.0)


def opt_state_bytes_per_param(name: str) -> float:
    return {"sgd": 0.0, "momentum": 4.0, "adam": 8.0}[name]
