"""Adaptive control vs every static schedule: time-to-ε on drifting fleets.

The claim (DESIGN.md §13): when the fleet drifts — diurnal participation
swings, block-persistent WAN outages — no single statically-priced
schedule is optimal for the whole run, and the closed-loop controller
(``repro.control``) strictly beats the *best* static schedule on
wall-clock time-to-ε while paying for its own re-solves.  When nothing
drifts, the controller must cost nothing: zero switches and a replay
bit-identical to the static optimum.

Three asserted scenarios:

1. **homogeneous-paper** — zero drift ⇒ the controller never re-solves,
   and adaptive time-to-ε EQUALS the static optimum exactly.
2. **diurnal-churn** (period ≫ window, deep night trough) — day wants
   large sync intervals (cheap agg amortization), night's 1/q-inflated
   drift penalty wants small ones; adaptive tracks the phase and strictly
   beats nominal, trace-p50+avg-q, day-optimal, and night-optimal statics.
3. **flaky-wan** (block-persistent outages) — storms reprice the fed
   links for whole blocks; adaptive strictly beats nominal/p50/p95.

Plus the control-step latency claim: a warm mid-run re-solve (windowed
tables memoized by the versioned evaluator + BCD seeded at the incumbent)
is ≥10× faster than cold re-pricing the same window from the trace and
solving from scratch — with the identical optimum, which the bit-exact
``WindowedLatency``-vs-``TraceLatency`` contract guarantees structurally.

Both replay arms use identical wall/progress ledgers
(``repro.control.replay``); the adaptive arm's ledger additionally pays
every re-solve's measured wall seconds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from .common import emit


def _fixture(seed: int, r_star: int):
    """The Sec. VII problem with ε anchored so the reference static
    schedule reaches ε in ~``r_star`` rounds (keeps replays short)."""
    from repro.configs.vgg16_cifar10 import SPEC as VGG
    from repro.core import (
        HsflProblem,
        SystemSpec,
        build_profile,
        synthetic_hyperspec,
    )
    from repro.core.convergence import theorem1_bound

    prof = build_profile(VGG, batch=2)
    system = SystemSpec.paper_three_tier(
        num_clients=20, num_edges=5, seed=seed
    )
    hp = synthetic_hyperspec(VGG.n_units, 20, seed=seed)
    eps = theorem1_bound(hp, r_star, (2, 2, 1), (3, 8))
    return prof, system, hp, eps, HsflProblem(prof, system, hp, eps)


def _replay_static(tr, hp, eps, res, rounds):
    from repro.control import replay

    return replay(tr, hp, eps, res.cuts, res.intervals, rounds=rounds)


def _replay_adaptive(tr, hp, eps, priced, start, rounds, **knobs):
    from repro.control import Controller, replay

    ctrl = Controller(
        priced, start.cuts, start.intervals, backend="numpy", **knobs
    )
    out = replay(
        tr, hp, eps, start.cuts, start.intervals, controller=ctrl,
        rounds=rounds,
    )
    return ctrl, out


def _rows_for(scenario: str, arms: Dict[str, object]) -> List[Tuple]:
    rows = []
    for name, out in arms.items():
        rows.append((
            scenario, name, f"{out.time_to_eps:.4f}",
            out.rounds_to_eps, out.n_switches,
            f"{out.solve_overhead:.4f}",
        ))
    return rows


# --------------------------------------------------------------------------- #
# 1. homogeneous-paper: zero drift => zero switches, exact equality
# --------------------------------------------------------------------------- #


def homogeneous_case(quick: bool, seed: int, r_star: int) -> List[Tuple]:
    from repro.core import solve_bcd
    from repro.sim import make_trace, robust_problem

    prof, system, hp, eps, base = _fixture(seed, r_star)
    tr = make_trace("homogeneous-paper", prof, system, rounds=32, seed=seed)
    priced = robust_problem(base, tr, quantile=0.5, backend="numpy")
    opt = solve_bcd(priced, backend="numpy")
    rounds = 4 * r_star

    static = _replay_static(tr, hp, eps, opt, rounds)
    ctrl, adaptive = _replay_adaptive(
        tr, hp, eps, priced, opt, rounds,
        window=8, cooldown=8, min_window=4, rel_tol=0.25, quantile=0.5,
    )

    assert static.reached and adaptive.reached, "ε must be reachable"
    assert ctrl.n_switches == 0, (
        f"homogeneous fleet must trigger zero switches, got {ctrl.n_switches}"
    )
    assert adaptive.time_to_eps == static.time_to_eps, (
        "zero-drift adaptive replay must equal the static optimum exactly: "
        f"{adaptive.time_to_eps} vs {static.time_to_eps}"
    )
    print(f"homogeneous-paper: zero switches, t-to-ε identical "
          f"({static.time_to_eps:.2f}s) ✓")
    return _rows_for(
        "homogeneous-paper", {"static-opt": static, "adaptive": adaptive}
    )


# --------------------------------------------------------------------------- #
# 2. diurnal-churn: participation phases
# --------------------------------------------------------------------------- #


def diurnal_case(quick: bool, seed: int, r_star: int) -> List[Tuple]:
    from repro.core import solve_bcd
    from repro.core.convergence import ParticipationSpec
    from repro.control import WindowedLatency
    from repro.sim import make_trace, robust_problem
    from repro.sim.participation import _tier_entity_rates

    prof, system, hp, eps, base = _fixture(seed, r_star)
    period = 96
    tr = make_trace(
        "diurnal-churn", prof, system, rounds=2 * period, seed=seed + 2,
        period=period, p_min=0.12, p_max=1.0,
    )
    rounds = 8 * r_star
    q_avg = np.stack([
        _tier_entity_rates(tr.round_state(r).available, system.entities)
        for r in range(tr.rounds)
    ]).mean(axis=0)

    statics = {}
    statics["nominal"] = solve_bcd(base, backend="numpy")
    p50 = robust_problem(base, tr, quantile=0.5, backend="numpy")
    p50q = dataclasses.replace(
        p50,
        participation=ParticipationSpec(
            q=tuple(float(v) for v in q_avg), deadline=None
        ),
    )
    statics["p50+avg-q"] = solve_bcd(p50q, backend="numpy")

    # phase oracles as static candidates: the day/night optima themselves
    lattice = base.cut_lattice()

    def phase_opt(rr):
        w = WindowedLatency(prof, system, lattice, window=len(rr), quantile=0.5)
        for r in rr:
            st = tr.round_state(r)
            w.push(st, mask=st.available)
        q = np.clip(w.q_tiers(), 1e-6, 1.0)
        p = dataclasses.replace(
            base, latency_model=w,
            participation=ParticipationSpec(
                q=tuple(float(v) for v in q), deadline=None
            ),
        )
        return solve_bcd(p, backend="numpy")

    statics["day-opt"] = phase_opt(range(12, 36))      # sinusoid crest
    statics["night-opt"] = phase_opt(range(60, 84))    # sinusoid trough

    arms = {
        f"static:{k}": _replay_static(tr, hp, eps, res, rounds)
        for k, res in statics.items()
    }
    ctrl, adaptive = _replay_adaptive(
        tr, hp, eps, p50q, statics["p50+avg-q"], rounds,
        window=8, cooldown=6, min_window=4, rel_tol=0.25, quantile=0.5,
    )
    arms["adaptive"] = adaptive

    best_name, best = min(
        ((k, v) for k, v in arms.items() if k != "adaptive"),
        key=lambda kv: kv[1].time_to_eps,
    )
    assert adaptive.reached, "adaptive arm must reach ε"
    assert adaptive.time_to_eps < best.time_to_eps, (
        "adaptive must strictly beat every static on diurnal-churn: "
        f"adaptive {adaptive.time_to_eps:.3f}s vs best static "
        f"{best_name} {best.time_to_eps:.3f}s"
    )
    print(f"diurnal-churn: adaptive {adaptive.time_to_eps:.2f}s beats best "
          f"static ({best_name}) {best.time_to_eps:.2f}s with "
          f"{adaptive.n_switches} switches ✓")
    return _rows_for("diurnal-churn", arms)


# --------------------------------------------------------------------------- #
# 3. flaky-wan: block-persistent outages
# --------------------------------------------------------------------------- #


def flaky_case(quick: bool, seed: int, r_star: int):
    from repro.core import solve_bcd
    from repro.sim import make_trace, robust_problem

    prof, system, hp, eps, base = _fixture(seed, r_star)
    block = 64
    tr = make_trace(
        "flaky-wan", prof, system, rounds=4 * block, seed=seed + 1,
        jitter_sigma=0.1, outage_p=0.3, outage_mult=0.02, outage_len=block,
    )
    rounds = 8 * r_star

    statics = {"nominal": solve_bcd(base, backend="numpy")}
    priced = {}
    for q in (0.5, 0.95):
        rp = robust_problem(base, tr, quantile=q, backend="numpy")
        priced[q] = rp
        statics[f"p{int(q * 100)}"] = solve_bcd(rp, backend="numpy")

    arms = {
        f"static:{k}": _replay_static(tr, hp, eps, res, rounds)
        for k, res in statics.items()
    }
    ctrl, adaptive = _replay_adaptive(
        tr, hp, eps, priced[0.5], statics["p50"], rounds,
        window=12, cooldown=8, min_window=4, rel_tol=0.25, quantile=0.5,
    )
    arms["adaptive"] = adaptive

    best_name, best = min(
        ((k, v) for k, v in arms.items() if k != "adaptive"),
        key=lambda kv: kv[1].time_to_eps,
    )
    assert adaptive.reached, "adaptive arm must reach ε"
    assert adaptive.time_to_eps < best.time_to_eps, (
        "adaptive must strictly beat every static on flaky-wan: "
        f"adaptive {adaptive.time_to_eps:.3f}s vs best static "
        f"{best_name} {best.time_to_eps:.3f}s"
    )
    print(f"flaky-wan: adaptive {adaptive.time_to_eps:.2f}s beats best "
          f"static ({best_name}) {best.time_to_eps:.2f}s with "
          f"{adaptive.n_switches} switches ✓")
    return _rows_for("flaky-wan", arms), ctrl, tr, base


# --------------------------------------------------------------------------- #
# 4. warm vs cold re-solve: the milliseconds claim
# --------------------------------------------------------------------------- #


def warm_vs_cold(ctrl, tr, base, quick: bool) -> List[Tuple]:
    """A control step (memoized windowed tables + warm-seeded BCD) vs the
    naive alternative: re-simulate the window into a fresh trace-quantile
    model and solve from scratch.  Same data, same optimum — asserted."""
    from repro.core import solve_bcd
    from repro.sim import TraceLatency
    from repro.sim.scenarios import SystemTrace

    reps = 3 if quick else 7
    wp = ctrl.windowed_problem()
    win = ctrl.window_model
    W = win.n_obs

    # the exact states the controller's window holds, as a fresh trace
    states = list(win.states())

    warm_t, cold_t = [], []
    warm_res = cold_res = None
    for _ in range(reps):
        t0 = time.perf_counter()
        warm_res = solve_bcd(
            wp, init_cuts=ctrl.cuts, init_intervals=ctrl.intervals,
            backend="numpy", warm_start=True,
        )
        warm_t.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        mini = SystemTrace(
            "window", base.profile, base.system, W, 0,
            lambda r: states[r],
        )
        cold_model = TraceLatency(
            mini, quantile=win.quantile, backend="numpy"
        )
        cold_p = dataclasses.replace(
            base, latency_model=cold_model, participation=wp.participation
        )
        cold_res = solve_bcd(cold_p, backend="numpy")
        cold_t.append(time.perf_counter() - t0)

    assert (warm_res.cuts, tuple(warm_res.intervals)) == \
           (cold_res.cuts, tuple(cold_res.intervals)), (
        "warm and cold re-solves must find the identical optimum: "
        f"{warm_res.cuts}x{warm_res.intervals} vs "
        f"{cold_res.cuts}x{cold_res.intervals}"
    )
    warm_p50 = float(np.median(warm_t))
    cold_p50 = float(np.median(cold_t))
    speedup = cold_p50 / warm_p50
    assert speedup >= 10.0, (
        f"warm control step must be >=10x a cold re-price+solve, got "
        f"{speedup:.1f}x (warm {1e3 * warm_p50:.2f}ms, "
        f"cold {1e3 * cold_p50:.2f}ms)"
    )
    resolve_p50, resolve_p95 = ctrl.resolve_quantiles((0.5, 0.95))
    print(f"warm re-solve {1e3 * warm_p50:.2f}ms vs cold "
          f"{1e3 * cold_p50:.2f}ms = {speedup:.1f}x; in-run re-solve "
          f"p50 {1e3 * resolve_p50:.2f}ms / p95 {1e3 * resolve_p95:.2f}ms ✓")
    return [
        ("resolve", "warm_p50_ms", f"{1e3 * warm_p50:.3f}", "", "", ""),
        ("resolve", "cold_p50_ms", f"{1e3 * cold_p50:.3f}", "", "", ""),
        ("resolve", "speedup_x", f"{speedup:.2f}", "", "", ""),
        ("resolve", "inrun_p50_ms", f"{1e3 * resolve_p50:.3f}", "", "", ""),
        ("resolve", "inrun_p95_ms", f"{1e3 * resolve_p95:.3f}", "", "", ""),
    ]


def main(quick: bool = False, seed: int = 0) -> list:
    r_star = 250 if quick else 600
    rows = []
    rows += homogeneous_case(quick, seed, r_star)
    rows += diurnal_case(quick, seed, r_star)
    flaky_rows, ctrl, tr, base = flaky_case(quick, seed, r_star)
    rows += flaky_rows
    rows += warm_vs_cold(ctrl, tr, base, quick)
    emit(rows, ("scenario", "arm", "t_to_eps_s", "rounds", "switches",
                "overhead_s"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    main(a.quick, seed=a.seed)
