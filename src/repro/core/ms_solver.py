"""P2 — the model-splitting sub-problem (Dinkelbach MILFP, Sec. VI).

For fixed intervals I, problem (27) is a mixed-integer linear *fractional*
program in (μ, T):  min N(μ)/D(μ)  with both N and D affine in the one-hot
cut indicators μ_{m,l} once the max-constraints R1–R3 are written out.

We solve it with the Dinkelbach parametric scheme [46]: repeatedly solve

    F(q) = min_μ  N(μ) − q · D(μ)   s.t. C2–C5, D(μ) > 0

and update q ← N(μ*)/D(μ*) until F(q) ≈ 0; the fixpoint is the global
optimum of the fraction. The inner parametric problem is solved *exactly*:
because every quantity is additive over tiers given the cut vector, and the
number of C2–C4-valid cut vectors is combinatorial-small
(≈ U^{M-1}/(M-1)! — e.g. 2,016 for U=64, M=3), an exact search over the
feasible lattice is both faster and stronger than an LP-relaxation MILP
here.

Two execution paths, bit-identical by construction (DESIGN.md §11):

* ``backend="scalar"`` walks the lattice one cut vector at a time through
  ``problem.numerator``/``denominator`` — the historical path, kept as
  the test oracle;
* ``backend="numpy"|"jax"|"auto"`` reads the problem's memoized
  ``BatchedEvaluator``: N and D for the whole lattice are precomputed
  arrays, so each Dinkelbach step is one argmin over ``[K]`` — this is
  what lets BCD re-run online at U=128/M=4 (~3·10⁵ lattice points).

``solve_ms_bruteforce`` (direct ratio enumeration) is the test oracle;
Dinkelbach must and does reach the same optimum on either path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .problem import INFEASIBLE, HsflProblem


@dataclass(frozen=True)
class MsSolution:
    cuts: Tuple[int, ...]
    theta: float
    dinkelbach_iters: int = 0


def _nd(problem: HsflProblem, intervals: Sequence[int], cuts) -> Tuple[float, float]:
    return (
        problem.numerator(intervals, cuts),
        problem.denominator(intervals, cuts),
    )


def _feasible_cuts(problem: HsflProblem, intervals: Sequence[int]) -> List[Tuple[int, ...]]:
    d_min = problem.d_min()  # 0.0 unconstrained: bit-identical to D <= 0
    out = []
    for cuts in problem.iter_cut_vectors():
        if not problem.memory_feasible(cuts):
            continue
        if problem.denominator(intervals, cuts) <= d_min:
            continue  # C1 unreachable (or over the ε budget's round cap)
        if not problem.energy_feasible(intervals, cuts):
            continue  # E(I, μ) over the per-round energy budget
        out.append(cuts)
    return out


_INFEASIBLE_MSG = (
    "MS sub-problem infeasible: no cut vector satisfies C2–C5 with "
    "a reachable convergence bound (try larger eps or smaller I; under a "
    "privacy/energy budget, loosen epsilon_budget or budget_j_per_round)."
)


def _solve_ms_scalar(
    problem: HsflProblem,
    intervals: Sequence[int],
    tol: float,
    max_iters: int,
    warm_cuts: Optional[Sequence[int]] = None,
) -> MsSolution:
    """The one-cut-at-a-time Dinkelbach walk (oracle path)."""
    feas = _feasible_cuts(problem, intervals)
    if not feas:
        raise ValueError(_INFEASIBLE_MSG)
    # initial q from the warm-start point when given (and feasible),
    # otherwise an arbitrary feasible point; Dinkelbach's fixpoint is the
    # global optimum of the fraction either way — a warm q just lands the
    # first parametric argmin near it, typically converging in one step
    start = feas[0]
    if warm_cuts is not None:
        w = tuple(int(c) for c in warm_cuts)
        if w in set(feas):
            start = w
    n0, d0 = _nd(problem, intervals, start)
    q = n0 / d0
    best = start
    for it in range(1, max_iters + 1):
        # inner parametric problem: exact search over the feasible lattice
        vals = []
        for cuts in feas:
            n, d = _nd(problem, intervals, cuts)
            vals.append(n - q * d)
        i = int(np.argmin(vals))
        best, fq = feas[i], vals[i]
        n, d = _nd(problem, intervals, best)
        new_q = n / d
        if abs(fq) <= tol * max(1.0, abs(q)) or abs(new_q - q) <= tol * max(1.0, abs(q)):
            q = new_q
            break
        q = new_q
    scale = 2.0 * problem.hyper.theta0 / problem.hyper.gamma
    return MsSolution(tuple(best), scale * q, dinkelbach_iters=it)


def solve_ms(
    problem: HsflProblem,
    intervals: Sequence[int],
    tol: float = 1e-9,
    max_iters: int = 64,
    backend: str = "auto",
    warm_cuts: Optional[Sequence[int]] = None,
) -> MsSolution:
    """Optimal cuts for fixed intervals via Dinkelbach over an exact backend.

    ``backend="scalar"`` re-walks the lattice per iteration (oracle);
    anything else evaluates the whole lattice through the problem's
    memoized ``BatchedEvaluator`` — identical iterates, identical optimum,
    to the last bit.

    ``warm_cuts`` seeds the Dinkelbach ratio q at a known-good cut vector
    (the adaptive controller passes the previous optimum): the fixpoint —
    and hence the returned optimum — is unchanged, but a warm q lets the
    first whole-lattice argmin land on (or next to) it, so a mid-run
    re-solve typically terminates in a single parametric step.
    """
    if backend == "scalar":
        return _solve_ms_scalar(problem, intervals, tol, max_iters, warm_cuts)
    ev = problem.evaluator(backend)
    nums = ev.numerator(intervals)
    dens = ev.denominator(intervals)
    ok = ev.mem_ok & (dens > ev.d_min)
    if ev.energy_budget is not None:
        ok = ok & (ev.round_energy(intervals) <= ev.energy_budget)
    feas = np.flatnonzero(ok)
    if feas.size == 0:
        raise ValueError(_INFEASIBLE_MSG)
    n, d = nums[feas], dens[feas]
    start = 0
    if warm_cuts is not None:
        w = np.flatnonzero((ev.lattice == np.asarray(warm_cuts)).all(axis=1))
        if w.size:
            hit = np.flatnonzero(feas == w[0])
            if hit.size:
                start = int(hit[0])
    q = n[start] / d[start]
    best_i = feas[start]
    for it in range(1, max_iters + 1):
        vals = n - q * d  # whole-lattice parametric step: one argmin
        j = int(np.argmin(vals))
        best_i, fq = feas[j], vals[j]
        new_q = n[j] / d[j]
        if abs(fq) <= tol * max(1.0, abs(q)) or abs(new_q - q) <= tol * max(1.0, abs(q)):
            q = new_q
            break
        q = new_q
    scale = 2.0 * problem.hyper.theta0 / problem.hyper.gamma
    return MsSolution(ev.cuts_at(int(best_i)), float(scale * q), dinkelbach_iters=it)


def solve_ms_bruteforce(
    problem: HsflProblem, intervals: Sequence[int]
) -> MsSolution:
    """Direct ratio enumeration (test oracle; reads the shared lattice)."""
    best_cuts, best_th = None, INFEASIBLE
    for cuts in problem.iter_cut_vectors():
        th = problem.theta(intervals, cuts)
        if th < best_th:
            best_cuts, best_th = cuts, th
    if best_cuts is None:
        raise ValueError("MS sub-problem infeasible")
    return MsSolution(tuple(best_cuts), best_th)
