"""Dry-run machinery: HLO collective parsing (pure) + one CLI smoke run."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun_lib import (
    collective_traffic_bytes, parse_collectives, _shape_bytes,
)

HLO_SAMPLE = """
  %all-reduce = f32[16,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,4]<=[16], use_global_device_ids=true, to_apply=%add
  %all-gather.1 = bf16[256,512]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[8,8]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[1,16]<=[16], to_apply=%add
  ROOT %all-to-all.2 = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b), replica_groups={{0,1,2,3}}
  %cp = u32[64]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_a_collective = f32[2,2]{1,0} add(%p, %q)
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[256,512]{1,0}") == 256 * 512 * 2
    assert _shape_bytes("(f32[4,4]{1,0}, f32[4,4]{1,0})") == 2 * 16 * 4
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1


def test_parse_collectives():
    colls = parse_collectives(HLO_SAMPLE)
    ops = sorted(c["op"] for c in colls)
    assert ops == sorted(
        ["all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"]
    )
    ar = next(c for c in colls if c["op"] == "all-reduce")
    assert ar["result_bytes"] == 16 * 128 * 4
    assert ar["group"] == 4
    rs = next(c for c in colls if c["op"] == "reduce-scatter")
    assert rs["group"] == 16


def test_traffic_model():
    colls = [
        {"op": "all-reduce", "result_bytes": 100, "group": 4},
        {"op": "all-gather", "result_bytes": 100, "group": 4},
        {"op": "reduce-scatter", "result_bytes": 10, "group": 4},
    ]
    t = collective_traffic_bytes(colls)
    assert t == 2 * 100 * 3 / 4 + 100 * 3 / 4 + 10 * 3


@pytest.mark.slow
def test_dryrun_cli_smoke(tmp_path):
    """Full 512-device lower+compile for the smallest arch (integration)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm-135m", "--shape", "train_4k", "--mesh", "pod",
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    files = list(tmp_path.iterdir())
    assert len(files) == 1
    meta = json.loads(files[0].read_text())
    assert meta["num_devices"] == 256
    assert meta["flops"] > 1e11
    assert meta["collective_bytes"] > 0
    assert "all-reduce" in meta["collectives"]


@pytest.mark.slow
def test_dryrun_perf_variants_smoke(tmp_path):
    """The perf-variant flags (seq-shard / kv-seq-shard / moe groups /
    round specialization) all lower+compile on the production mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    runs = [
        ["--arch", "smollm-135m", "--shape", "train_4k",
         "--seq-shard", "--round", "local", "--tag", "t1"],
        ["--arch", "smollm-135m", "--shape", "decode_32k",
         "--cache-seq-shard", "--donate-cache", "--tag", "t2"],
        ["--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
         "--moe-shard", "--tag", "t3"],
    ]
    for extra in runs:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--mesh", "pod", "--out", str(tmp_path), *extra],
            env=env, capture_output=True, text=True, timeout=560,
        )
        assert out.returncode == 0, (extra, out.stderr[-2000:])
    assert len(list(tmp_path.iterdir())) == 3
